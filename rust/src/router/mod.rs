//! Request router: the leader loop connecting the HTTP front end to
//! engine worker threads.
//!
//! PJRT objects are not `Send`, so each worker thread constructs its own
//! backend ([`Runtime`] + `PjrtBackend`, or the simulation backend) and
//! owns one [`GroupScheduler`]. Two scheduling modes:
//!
//!   * [`SchedMode::Continuous`] (default) — the worker keeps a fixed
//!     set of batch slots hot: finished sequences retire at block
//!     boundaries and queued requests are admitted into the freed slots
//!     mid-flight, so one slow sequence never holds finished slots
//!     hostage and arrivals don't wait for the group to drain;
//!   * [`SchedMode::RunToCompletion`] — the pre-refactor behavior
//!     (drain a batch, run it to completion), kept as the baseline the
//!     `serve_continuous` bench compares against.
//!
//! In continuous mode a worker owns every batch class
//! ([`crate::batcher::batch_classes`]: the b=1 lone-request class plus
//! the full `batcher.max_batch` class) and resizes between them from
//! demand at block boundaries ([`GroupScheduler::maybe_switch_class`]):
//! a lone request gets the latency-optimal b=1 executables back, a deep
//! queue upshifts to the full batch. All workers share one
//! [`ResidencyPool`], so a class switch — or a second worker — resumes
//! a parked retained chain instead of re-seeding full KV over the bus
//! (PJRT workers park under their own owner id behind the non-`Send`
//! constraint; the sim backend models true cross-worker sharing). The
//! pool's cumulative ledger is mirrored into the `/metrics` gauges
//! (`resident_chains`, `chain_switches`, `chain_rebuilds_avoided`,
//! `reseed_bytes_saved`) every tick.
//!
//! All workers also share one cross-request [`PrefixCache`]
//! ([`PREFIX_CACHE_BUDGET`] bytes, LRU): a retiring sequence offers its
//! block-aligned prompt prefix, and a later admission sharing it
//! (multi-turn chat, common system prompts) seeds its prompt-region KV
//! rows from the cache instead of re-running the grounding prefill over
//! the shared prefix. Its cumulative ledger is mirrored into
//! `/metrics` the same way (`prefix_hits`, `prefix_misses`,
//! `prefill_bytes_saved`, `prefix_cache_bytes`, `prefix_evictions`).
//!
//! Requests carry per-request parameters ([`SeqParams`]: `gen_len`,
//! temperature, parallel threshold, `timeout_ms`, and an [`SloClass`])
//! and replies carry true per-request statistics ([`GenReply`]), not
//! group-level aggregates. Responses travel back through per-request
//! oneshot slots, protected by a [`PendingRepliesGuard`]: a worker that
//! panics mid-flight answers every outstanding oneshot with an error
//! during unwind instead of leaving clients blocked forever.
//!
//! # SLO-aware admission, shedding, and preemption
//!
//! The shared queue is a set of per-class priority lanes
//! ([`SloQueues`]: one [`VecDeque`] per [`SloClass`]) behind one
//! bounded capacity. Under the default [`SloPolicy::SloAware`] policy
//! workers drain the highest-priority non-empty lane first; under
//! [`SloPolicy::Fifo`] (the baseline the SLO bench compares against)
//! arrival stamps restore global FIFO order and queue-full `try_submit`
//! fails plainly → HTTP 503.
//!
//! Overload never hangs and never fails silently — the error taxonomy
//! is explicit:
//!
//!   * `overloaded:` (→ HTTP 429) — the queue is at capacity. Under
//!     `SloAware` an arrival outranking a queued lower-class request
//!     sheds that victim's oneshot and takes its place; an arrival that
//!     outranks nobody is shed itself. Either way a structured reply is
//!     delivered, never a silent drop ([`Metrics::shed_total`]).
//!   * `timeout:` (→ HTTP 504) — deadline-aware admission: a request
//!     whose `timeout_ms` budget already burned away while queued is
//!     shed at admission, before a grounding prefill is wasted on it.
//!     The same prefix covers in-flight deadline overruns detected at
//!     block boundaries and parked victims whose deadline expires.
//!   * fault errors (→ HTTP 500) — the recovery ladder below.
//!
//! When a request arrives whose class outranks a resident sequence and
//! no slot is free, the worker **preempts at a block boundary**:
//! [`GroupScheduler::preempt_victim`] parks the victim's host state and
//! token rows (block boundaries are where the next plan is a grounding
//! prefill, so park/resume is trajectory-exact — token-identical to an
//! unpreempted run), the waiter is admitted into the freed slot, and
//! [`GroupScheduler::resume_victim`] re-seats the victim when pressure
//! drops. Preempt/resume/shed events land in the shared pool ledger and
//! are mirrored to `/metrics` (`esdllm_preemptions_total`,
//! `esdllm_resumed_total`, `esdllm_victims_parked`, `esdllm_shed_total`)
//! alongside per-class TTFT/TPOT histograms.
//!
//! # Fault recovery
//!
//! [`tick_once`] is the recovery loop. A failed tick is classified
//! ([`crate::fault::classify`]) and handled by class:
//!
//!   * **transient** (injected exec/transfer/alloc fault) — invalidate
//!     the active class, re-ground it
//!     ([`GroupScheduler::reground_active`]), back off exponentially,
//!     and re-tick within a bounded per-tick retry budget. The failed
//!     tick never mutated the trajectory, so recovered sequences
//!     produce token-identical output and unaffected sequences never
//!     see an error;
//!   * **poisoned** (fused committed-count divergence) — as transient,
//!     but the fused dispatch depth steps down one rung first
//!     (k → k/2 → 1, [`GroupScheduler::demote_fused_k`]);
//!   * **misconfiguration** (anything untyped) — retrying cannot help:
//!     fail exactly the resident sequences and evict, keeping the
//!     worker alive for the next request.
//!
//! Repeated consecutive faults escalate the degradation ladder: the
//! backend is quarantined to `ApplyMode::Host`
//! ([`GroupScheduler::set_apply_override`]) and re-probed back to
//! device apply after a clean-tick cool-down. Every action lands in
//! the backend's [`crate::fault::FaultStats`] ledger, pumped into the
//! `/metrics` fault counters each tick alongside the transfer ledger.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::batcher::{batch_classes, BatcherCfg};
use crate::engine::EngineCfg;
use crate::fault::{classify, FaultStats, TickErrorClass};
use crate::metrics::Metrics;
use crate::runtime::resident::{ApplyMode, PoolStats, PrefixCache, PrefixStats, ResidencyPool};
use crate::runtime::Runtime;
use crate::scheduler::sim::{SimBackend, SimCfg};
use crate::scheduler::{
    GroupScheduler, PjrtBackend, ResumeOutcome, SchedCfg, SeqInput, SeqParams, SloClass,
    StepBackend,
};

/// Re-ticks after a failed (and re-grounded) tick before the resident
/// sequences are failed: the bounded per-tick retry budget.
const TICK_RETRY_BUDGET: u32 = 3;
/// Consecutive faulted ticks before the device-apply path is
/// quarantined to `ApplyMode::Host`.
const QUARANTINE_AFTER: u32 = 3;
/// Clean ticks under quarantine before re-probing device apply.
const REPROBE_AFTER: u64 = 64;
/// Byte budget of the shared cross-request prefix KV cache (host
/// memory; LRU past this). Generous against the nano artifact geometry
/// — a prompt-region payload there is a few KiB — while still bounding
/// a long-running server's footprint.
pub const PREFIX_CACHE_BUDGET: u64 = 64 << 20;

pub struct GenRequest {
    pub prompt: String,
    pub params: SeqParams,
    pub submitted: Instant,
    reply: OneShot<Result<GenReply, String>>,
}

/// Per-request generation outcome (replaces the old group-level reply).
#[derive(Debug, Clone)]
pub struct GenReply {
    pub text: String,
    /// iterations THIS sequence was stepped
    pub iterations: usize,
    /// admission → completion
    pub wall_s: f64,
    /// submit → admission (time spent queued)
    pub queue_s: f64,
    /// positions decoded — content plus EOS fill (≤ requested gen_len
    /// on EOS-guard early exit)
    pub tokens: usize,
}

/// Minimal oneshot built on Mutex + Condvar.
pub struct OneShot<T>(Arc<(Mutex<Option<T>>, Condvar)>);

impl<T> Clone for OneShot<T> {
    fn clone(&self) -> Self {
        OneShot(self.0.clone())
    }
}

impl<T> OneShot<T> {
    pub fn new() -> Self {
        OneShot(Arc::new((Mutex::new(None), Condvar::new())))
    }

    pub fn put(&self, v: T) {
        *self.0 .0.lock().unwrap() = Some(v);
        self.0 .1.notify_all();
    }

    pub fn wait(&self) -> T {
        let mut g = self.0 .0.lock().unwrap();
        loop {
            if let Some(v) = g.take() {
                return v;
            }
            g = self.0 .1.wait(g).unwrap();
        }
    }

    /// Wait up to `dur` for the value; `None` on timeout. The HTTP
    /// handler bounds its wait with this so a wedged worker can never
    /// hang a client connection forever.
    pub fn wait_timeout(&self, dur: Duration) -> Option<T> {
        let deadline = Instant::now() + dur;
        let mut g = self.0 .0.lock().unwrap();
        loop {
            if let Some(v) = g.take() {
                return Some(v);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            g = self.0 .1.wait_timeout(g, deadline - now).unwrap().0;
        }
    }
}

impl<T> Default for OneShot<T> {
    fn default() -> Self {
        Self::new()
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedMode {
    /// slot scheduler with mid-flight admission at block boundaries
    Continuous,
    /// legacy drain-batch → run-to-completion (baseline for benches)
    RunToCompletion,
}

/// How a worker obtains its [`StepBackend`].
#[derive(Clone)]
pub enum WorkerBackend {
    /// load the PJRT runtime + compiled artifacts from `artifacts_dir`
    Pjrt,
    /// deterministic simulation backend (tests, scheduler benches)
    Sim(SimCfg),
}

/// Admission/dispatch policy of the shared request queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SloPolicy {
    /// global arrival order; queue-full `try_submit` → Err (HTTP 503).
    /// The no-shed, no-preempt baseline the SLO bench compares against.
    Fifo,
    /// per-class priority dispatch, lowest-class load shedding under
    /// overload, and block-boundary preemption (see the module docs)
    #[default]
    SloAware,
}

/// Outcome of pushing a request into [`SloQueues`].
enum Pushed {
    Ok,
    /// the queue was full of equal-or-higher classes: the incoming
    /// request itself is the shed victim (non-blocking push only)
    Overloaded(GenRequest),
    /// the incoming request outranked a queued lower-class request:
    /// that victim was popped to make room and must be answered with a
    /// structured `overloaded:` error
    Shed(GenRequest),
    /// the router is shutting down
    Closed,
}

struct SloQueuesInner {
    /// one lane per [`SloClass`], indexed by `SloClass::index()`;
    /// entries carry a global arrival stamp so the FIFO policy can
    /// restore arrival order across lanes
    lanes: [VecDeque<(u64, GenRequest)>; SloClass::COUNT],
    arrivals: u64,
    closed: bool,
}

/// The router's bounded multi-lane request queue: one FIFO lane per
/// [`SloClass`] behind a single shared capacity, replacing the old
/// single [`crate::threadpool::Channel`].
struct SloQueues {
    inner: Mutex<SloQueuesInner>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
    policy: SloPolicy,
}

impl SloQueues {
    fn new(cap: usize, policy: SloPolicy) -> SloQueues {
        SloQueues {
            inner: Mutex::new(SloQueuesInner {
                lanes: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
                arrivals: 0,
                closed: false,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cap,
            policy,
        }
    }

    fn push(&self, req: GenRequest, blocking: bool) -> Pushed {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed {
                drop(req);
                return Pushed::Closed;
            }
            let total: usize = g.lanes.iter().map(|l| l.len()).sum();
            if total < self.cap {
                let stamp = g.arrivals;
                g.arrivals += 1;
                g.lanes[req.params.slo.index()].push_back((stamp, req));
                self.not_empty.notify_one();
                return Pushed::Ok;
            }
            if self.policy == SloPolicy::SloAware {
                // full: shed the newest queued request of the lowest
                // class strictly below the incoming one, if any — the
                // explicit overload controller
                let victim_lane = (req.params.slo.index() + 1..SloClass::COUNT)
                    .rev()
                    .find(|&i| !g.lanes[i].is_empty());
                if let Some(i) = victim_lane {
                    let (_, victim) = g.lanes[i].pop_back().unwrap();
                    let stamp = g.arrivals;
                    g.arrivals += 1;
                    g.lanes[req.params.slo.index()].push_back((stamp, req));
                    self.not_empty.notify_one();
                    return Pushed::Shed(victim);
                }
            }
            if !blocking {
                return Pushed::Overloaded(req);
            }
            g = self.not_full.wait(g).unwrap();
        }
    }

    /// Pop under the policy: SLO-aware takes the highest-priority
    /// non-empty lane's head; FIFO takes the globally oldest arrival.
    fn pop_locked(policy: SloPolicy, g: &mut SloQueuesInner) -> Option<GenRequest> {
        let lane = match policy {
            SloPolicy::SloAware => (0..SloClass::COUNT).find(|&i| !g.lanes[i].is_empty()),
            SloPolicy::Fifo => g
                .lanes
                .iter()
                .enumerate()
                .filter_map(|(i, l)| l.front().map(|(stamp, _)| (*stamp, i)))
                .min()
                .map(|(_, i)| i),
        }?;
        let (_, req) = g.lanes[lane].pop_front().unwrap();
        Some(req)
    }

    fn recv(&self) -> Option<GenRequest> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(r) = Self::pop_locked(self.policy, &mut g) {
                self.not_full.notify_one();
                return Some(r);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    fn try_recv(&self) -> Option<GenRequest> {
        let mut g = self.inner.lock().unwrap();
        let r = Self::pop_locked(self.policy, &mut g);
        if r.is_some() {
            self.not_full.notify_one();
        }
        r
    }

    fn recv_timeout(&self, dur: Duration) -> Option<GenRequest> {
        let deadline = Instant::now() + dur;
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(r) = Self::pop_locked(self.policy, &mut g) {
                self.not_full.notify_one();
                return Some(r);
            }
            if g.closed {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            g = self.not_empty.wait_timeout(g, deadline - now).unwrap().0;
        }
    }

    fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    fn len(&self) -> usize {
        self.inner.lock().unwrap().lanes.iter().map(|l| l.len()).sum()
    }

    /// Class of the best queued request (the one a worker would pop
    /// next under SLO-aware dispatch), `None` when empty.
    fn peek_class(&self) -> Option<SloClass> {
        let g = self.inner.lock().unwrap();
        SloClass::ALL.into_iter().find(|c| !g.lanes[c.index()].is_empty())
    }
}

#[derive(Clone)]
pub struct Router {
    queue: Arc<SloQueues>,
    pub metrics: Arc<Metrics>,
}

pub struct RouterCfg {
    pub engine: EngineCfg,
    pub batcher: BatcherCfg,
    pub queue_cap: usize,
    pub workers: usize,
    pub artifacts_dir: std::path::PathBuf,
    pub mode: SchedMode,
    pub backend: WorkerBackend,
    pub policy: SloPolicy,
    /// opt into live-context decoding: every worker's scheduler tiers
    /// the compiled context to the live decode frontier (see
    /// [`GroupScheduler::enable_live_ctx`]). Off by default — the
    /// untiered dispatch/ledger behavior stays bit-identical.
    pub live_ctx: bool,
    /// override of the parked-victim aging interval in milliseconds
    /// (`None` keeps the scheduler default; `Some(0)` promotes
    /// immediately — tests)
    pub park_promote_ms: Option<u64>,
}

impl RouterCfg {
    /// Continuous scheduling over the PJRT runtime with default batcher
    /// and queue settings; override fields as needed.
    pub fn new(engine: EngineCfg, artifacts_dir: std::path::PathBuf) -> RouterCfg {
        RouterCfg {
            engine,
            batcher: BatcherCfg::default(),
            queue_cap: 256,
            workers: 1,
            artifacts_dir,
            mode: SchedMode::Continuous,
            backend: WorkerBackend::Pjrt,
            policy: SloPolicy::SloAware,
            live_ctx: false,
            park_promote_ms: None,
        }
    }
}

impl Router {
    /// Spawn worker threads and return the router handle. Each worker owns
    /// a full backend (PJRT client + compiled executables + params, or the
    /// simulation model) plus one slot scheduler.
    pub fn start(cfg: RouterCfg) -> Router {
        let queue = Arc::new(SloQueues::new(cfg.queue_cap.max(1), cfg.policy));
        let metrics = Arc::new(Metrics::default());
        metrics.start_clock();
        // one residency pool for every worker: parked retained chains
        // survive batch-class churn and are shared across workers (see
        // the module docs for the PJRT owner-id caveat)
        let pool = ResidencyPool::new();
        // and one cross-request prefix cache: retiring prompts' KV
        // prefixes outlive their slots here, so later admissions with a
        // shared prefix skip that much grounding prefill
        let prefix = PrefixCache::new(PREFIX_CACHE_BUDGET);
        for w in 0..cfg.workers.max(1) {
            let queue = queue.clone();
            let metrics = metrics.clone();
            let engine_cfg = cfg.engine.clone();
            let batcher = cfg.batcher;
            let dir = cfg.artifacts_dir.clone();
            let mode = cfg.mode;
            let backend = cfg.backend.clone();
            let pool = pool.clone();
            let prefix = prefix.clone();
            let tuning = WorkerTuning {
                live_ctx: cfg.live_ctx,
                park_promote_ms: cfg.park_promote_ms,
            };
            std::thread::Builder::new()
                .name(format!("engine-{w}"))
                .spawn(move || {
                    worker_loop(
                        queue, metrics, engine_cfg, batcher, dir, mode, backend, pool, prefix,
                        tuning, w,
                    )
                })
                .expect("spawn engine worker");
        }
        Router { queue, metrics }
    }

    fn enqueue(
        &self,
        prompt: String,
        params: SeqParams,
        blocking: bool,
    ) -> Result<OneShot<Result<GenReply, String>>, ()> {
        let class = params.slo;
        let reply = OneShot::new();
        let req = GenRequest {
            prompt,
            params,
            submitted: Instant::now(),
            reply: reply.clone(),
        };
        match self.queue.push(req, blocking) {
            Pushed::Ok => {
                self.metrics.requests_total.inc();
                Ok(reply)
            }
            Pushed::Shed(victim) => {
                // the newcomer outranked a queued lower-class request:
                // that victim gets a structured overload reply and the
                // newcomer takes its place
                self.metrics.requests_total.inc();
                self.metrics.shed_total.inc();
                victim.reply.put(Err(format!(
                    "overloaded: queue full (cap {}); shed for a {} arrival",
                    self.queue.cap,
                    class.name()
                )));
                Ok(reply)
            }
            Pushed::Overloaded(req) => {
                self.metrics.requests_rejected.inc();
                if self.queue.policy == SloPolicy::Fifo {
                    // baseline backpressure: plain queue-full → 503
                    Err(())
                } else {
                    // SLO-aware overload is always a structured reply,
                    // never a silent drop: the request outranked nothing
                    // queued, so it is the shed victim itself
                    self.metrics.requests_total.inc();
                    self.metrics.shed_total.inc();
                    req.reply.put(Err(format!(
                        "overloaded: queue full (cap {}) of equal-or-higher classes",
                        self.queue.cap
                    )));
                    Ok(reply)
                }
            }
            Pushed::Closed => Err(()),
        }
    }

    /// Enqueue a request; returns a oneshot to wait on. Err means the
    /// router is shut down — or, under [`SloPolicy::Fifo`], that the
    /// queue is full (backpressure → HTTP 503). Under the default
    /// SLO-aware policy overload is answered through the oneshot with a
    /// structured `overloaded:` error (→ HTTP 429) instead.
    #[allow(clippy::result_unit_err)]
    pub fn try_submit(
        &self,
        prompt: String,
        params: SeqParams,
    ) -> Result<OneShot<Result<GenReply, String>>, ()> {
        self.enqueue(prompt, params, false)
    }

    /// Blocking submit (used by the load generator / tests).
    #[allow(clippy::result_unit_err)]
    pub fn submit(
        &self,
        prompt: String,
        params: SeqParams,
    ) -> Result<OneShot<Result<GenReply, String>>, ()> {
        self.enqueue(prompt, params, true)
    }

    pub fn shutdown(&self) {
        self.queue.close();
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }
}

fn drain_with_error(queue: &SloQueues, msg: &str) {
    while let Some(req) = queue.recv() {
        req.reply.put(Err(msg.to_string()));
    }
}

/// Scheduler knobs each worker applies after construction (the
/// [`RouterCfg`] subset that isn't engine or batcher config).
#[derive(Clone, Copy)]
struct WorkerTuning {
    live_ctx: bool,
    park_promote_ms: Option<u64>,
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    queue: Arc<SloQueues>,
    metrics: Arc<Metrics>,
    engine_cfg: EngineCfg,
    batcher: BatcherCfg,
    artifacts_dir: std::path::PathBuf,
    mode: SchedMode,
    backend_kind: WorkerBackend,
    pool: Arc<ResidencyPool>,
    prefix: Arc<PrefixCache>,
    tuning: WorkerTuning,
    worker: usize,
) {
    let slots = batcher.max_batch.max(1);
    // batch classes a continuous worker may switch between; the PJRT arm
    // narrows this to what the compiled artifacts actually serve
    let mut classes = batch_classes(slots);
    // the runtime (when used) must outlive the backend borrowing it
    let mut rt_holder: Option<Runtime> = None;
    let backend: Box<dyn StepBackend + '_> = match backend_kind {
        WorkerBackend::Pjrt => {
            // the compiled artifacts exist only for batch classes {1, 8};
            // fail fast with a clear message instead of answering every
            // request with a confusing missing-executable error
            if slots != 1 && slots != 8 {
                let msg = format!(
                    "batcher.max_batch {slots} unsupported by the compiled \
                     executables (batch classes 1 and 8 only)"
                );
                log::error!("engine worker misconfigured: {msg}");
                drain_with_error(&queue, &msg);
                return;
            }
            let rt = match Runtime::load(&artifacts_dir) {
                Ok(rt) => rt,
                Err(e) => {
                    log::error!("engine worker failed to load runtime: {e:#}");
                    drain_with_error(&queue, &format!("runtime unavailable: {e}"));
                    return;
                }
            };
            let rt = rt_holder.insert(rt);
            // PJRT chains park under this worker's unique owner id —
            // their device buffers never leave this thread
            match PjrtBackend::with_pool(rt, engine_cfg.clone(), slots, pool, Some(worker as u64))
            {
                Ok(mut b) => {
                    classes = b.supported_classes(&classes);
                    b.set_prefix_cache(prefix);
                    Box::new(b)
                }
                Err(e) => {
                    log::error!("engine worker failed to build backend: {e:#}");
                    drain_with_error(&queue, &format!("backend unavailable: {e}"));
                    return;
                }
            }
        }
        WorkerBackend::Sim(mut sim_cfg) => {
            // the CLI's --fault-plan lands in EngineCfg; flow it into sim
            // workers unless the sim config carries its own plan already
            if sim_cfg.fault_plan.is_empty() {
                sim_cfg.fault_plan = engine_cfg.fault_plan.clone();
            }
            let mut b = SimBackend::with_pool(sim_cfg, pool);
            b.set_prefix_cache(prefix);
            Box::new(b)
        }
    };
    // continuous mode gets every batch class and switches between them
    // from demand; run-to-completion keeps the single full class (its
    // drain-a-batch loop never sizes down mid-batch)
    let sched = match mode {
        SchedMode::Continuous => GroupScheduler::with_classes(
            backend,
            &classes,
            SchedCfg::from_engine(&engine_cfg),
        ),
        SchedMode::RunToCompletion => {
            GroupScheduler::new(backend, slots, SchedCfg::from_engine(&engine_cfg))
        }
    };
    let mut sched = match sched {
        Ok(s) => s,
        Err(e) => {
            log::error!("engine worker failed to build scheduler: {e:#}");
            drain_with_error(&queue, &format!("scheduler unavailable: {e}"));
            return;
        }
    };
    sched.enable_live_ctx(tuning.live_ctx);
    if let Some(ms) = tuning.park_promote_ms {
        sched.set_park_promote(Some(Duration::from_millis(ms)));
    }
    // additive: several workers contribute to one capacity gauge
    metrics.slots_total.add(slots as u64);
    match mode {
        SchedMode::Continuous => run_continuous(sched, queue, metrics),
        SchedMode::RunToCompletion => run_to_completion(sched, queue, metrics, batcher),
    }
}

/// Publishes this worker's occupied-slot count into the shared
/// `active_slots` gauge as deltas — and, via `Drop`, takes the whole
/// contribution back when the worker exits or unwinds mid-flight.
/// Without the drop-guard a worker that returned early (or panicked
/// between a sync and its reply) left its last delta in the gauge
/// forever, permanently inflating `esdllm_active_slots`.
struct ActiveSlotsGuard {
    metrics: Arc<Metrics>,
    last: usize,
}

impl ActiveSlotsGuard {
    fn new(metrics: Arc<Metrics>) -> ActiveSlotsGuard {
        ActiveSlotsGuard { metrics, last: 0 }
    }

    /// Publish the current occupied-slot count as a delta against the
    /// previous contribution, so workers sharing the gauge never stomp
    /// each other.
    fn sync(&mut self, now: usize) {
        if now > self.last {
            self.metrics.active_slots.add((now - self.last) as u64);
        } else {
            self.metrics.active_slots.sub((self.last - now) as u64);
        }
        self.last = now;
    }
}

impl Drop for ActiveSlotsGuard {
    fn drop(&mut self) {
        self.metrics.active_slots.sub(self.last as u64);
        self.last = 0;
    }
}

/// Owns the in-flight reply slots. If the worker unwinds — panic in the
/// backend, in metrics plumbing, anywhere between admission and reply —
/// `Drop` answers every outstanding oneshot with an error instead of
/// leaving those clients blocked on `wait()` forever. On a clean exit
/// the map is empty and the drop is a no-op.
struct PendingRepliesGuard {
    pending: HashMap<u64, OneShot<Result<GenReply, String>>>,
}

impl PendingRepliesGuard {
    fn new() -> PendingRepliesGuard {
        PendingRepliesGuard { pending: HashMap::new() }
    }
}

impl std::ops::Deref for PendingRepliesGuard {
    type Target = HashMap<u64, OneShot<Result<GenReply, String>>>;
    fn deref(&self) -> &Self::Target {
        &self.pending
    }
}

impl std::ops::DerefMut for PendingRepliesGuard {
    fn deref_mut(&mut self) -> &mut Self::Target {
        &mut self.pending
    }
}

impl Drop for PendingRepliesGuard {
    fn drop(&mut self) {
        for (_, reply) in self.pending.drain() {
            reply.put(Err("engine worker terminated mid-flight".to_string()));
        }
    }
}

/// Per-worker degradation-ladder state: tracks the consecutive-fault
/// streak that triggers Host quarantine, the clean-tick cool-down that
/// re-probes device apply, and the last [`FaultStats`] snapshot so the
/// ledger can be pumped into the metrics as deltas.
struct RecoveryState {
    consecutive_faults: u32,
    quarantined: bool,
    clean_since_quarantine: u64,
    last_fault_stats: FaultStats,
}

impl RecoveryState {
    fn new() -> RecoveryState {
        RecoveryState {
            consecutive_faults: 0,
            quarantined: false,
            clean_since_quarantine: 0,
            last_fault_stats: FaultStats::default(),
        }
    }
}

/// Mirror the backend's cumulative [`FaultStats`] ledger into the
/// serving counters as deltas against the last snapshot.
fn pump_fault_stats(sched: &GroupScheduler<'_>, metrics: &Metrics, recovery: &mut RecoveryState) {
    if let Some(inj) = sched.fault_injector() {
        let now = inj.stats();
        let d = now.since(&recovery.last_fault_stats);
        metrics.faults_injected.add(d.faults_injected);
        metrics.ticks_retried.add(d.ticks_retried);
        metrics.chains_regrounded.add(d.chains_regrounded);
        metrics.fused_k_demotions.add(d.fused_k_demotions);
        metrics.host_demotions.add(d.host_demotions);
        metrics.requests_failed.add(d.requests_failed);
        recovery.last_fault_stats = now;
    }
}

/// Terminal arm of the recovery ladder: answer every resident sequence
/// with the error, evict the group, and zero this worker's slot gauge.
/// The worker itself stays alive for the next request.
fn fail_active(
    sched: &mut GroupScheduler<'_>,
    pending: &mut PendingRepliesGuard,
    guard: &mut ActiveSlotsGuard,
    msg: &str,
) {
    // parked preemption victims are in flight too — their clients are
    // waiting on the same oneshots, so an eviction must answer them
    let mut ids = sched.active_ids();
    ids.extend(sched.parked_ids());
    if let Some(inj) = sched.fault_injector() {
        inj.note_requests_failed(ids.len() as u64);
    }
    for id in ids {
        if let Some(reply) = pending.remove(&id) {
            reply.put(Err(msg.to_string()));
        }
    }
    sched.evict_all();
    guard.sync(0);
}

/// Shared per-tick bookkeeping: run one tick (retrying recoverable
/// faults within [`TICK_RETRY_BUDGET`]), update metrics, and answer the
/// retired sequences. Returns false after an unrecoverable error (all
/// resident sequences were failed and evicted).
fn tick_once(
    sched: &mut GroupScheduler<'_>,
    metrics: &Metrics,
    pending: &mut PendingRepliesGuard,
    guard: &mut ActiveSlotsGuard,
    recovery: &mut RecoveryState,
) -> bool {
    let mut attempt: u32 = 0;
    let outcome = loop {
        let busy = sched.active();
        let before = (sched.n_prefill, sched.n_dual, sched.n_es);
        let tiers_before = sched.tier_switches;
        let tr_before = sched.transfer_stats();
        let t0 = Instant::now();
        let tick_result = sched.tick();
        // resident-cache transfer accounting: this tick's ledger delta.
        // Pumped on both arms — a failed tick may already have synced and
        // recorded bytes, and the next snapshot would silently swallow them.
        let tr = sched.transfer_stats().since(&tr_before);
        metrics.upload_bytes.add(tr.upload_bytes);
        metrics.upload_bytes_saved.add(tr.upload_bytes_saved);
        metrics
            .kv_upload_bytes
            .add(tr.kv_upload_bytes + tr.kv_sparse_upload_bytes);
        metrics.ind_upload_bytes.add(tr.ind_upload_bytes);
        metrics.conf_upload_bytes.add(tr.conf_upload_bytes);
        metrics.token_upload_bytes.add(tr.token_upload_bytes);
        metrics.full_kv_uploads.add(tr.full_kv_uploads);
        metrics.resident_reuses.add(tr.resident_reuses);
        metrics.retained_out_reuses.add(tr.retained_out_reuses);
        metrics.d2h_bytes_avoided.add(tr.d2h_bytes_avoided);
        metrics.ingraph_conf_steps.add(tr.ingraph_conf_steps);
        metrics.d2h_bytes_shipped.add(tr.d2h_bytes_shipped);
        metrics.d2h_bytes_saved.add(tr.d2h_bytes_saved);
        metrics.donated_execs.add(tr.donated_execs);
        metrics.fused_execs.add(tr.fused_execs);
        metrics.inner_iters_fused.add(tr.inner_iters_fused);
        metrics.dispatches_avoided.add(tr.dispatches_avoided);
        // live-context decoding ledger: per-worker deltas into shared
        // gauges (`Gauge::add` composes across workers like the
        // counters do; with tiering off every delta is zero except the
        // row ticks, which then track the full context exactly)
        metrics.live_ctx_rows.add(tr.live_row_ticks);
        metrics.full_ctx_rows.add(tr.full_row_ticks);
        metrics.suffix_blocks_pruned.add(tr.suffix_blocks_pruned);
        metrics.early_retired_blocks.add(tr.early_retired_blocks);
        metrics.flops_units.add(tr.flops_units);
        metrics
            .tier_switches
            .add((sched.tier_switches - tiers_before) as u64);
        // pooled-residency ledger: the pool is shared by every worker, so
        // its cumulative values are mirrored (set), not delta-added
        let ps: PoolStats = sched.pool_stats();
        metrics.resident_chains.set(ps.resident_chains);
        metrics.chain_switches.set(ps.chain_switches);
        metrics.chain_rebuilds_avoided.set(ps.chain_rebuilds_avoided);
        metrics.reseed_bytes_saved.set(ps.reseed_bytes_saved);
        // preemption ledger: parked/resumed/dropped victims flow into
        // the pool from every worker, mirrored like the rest
        metrics.preemptions_total.set(ps.preemptions);
        metrics.resumed_total.set(ps.victim_resumes);
        metrics.victims_parked.set(ps.victims_parked);
        // prefix-cache ledger: shared by every worker like the pool's,
        // so mirrored (set), not delta-added
        let xs: PrefixStats = sched.prefix_stats();
        metrics.prefix_hits.set(xs.prefix_hits);
        metrics.prefix_misses.set(xs.prefix_misses);
        metrics.prefill_bytes_saved.set(xs.prefill_bytes_saved);
        metrics.prefix_cache_bytes.set(xs.prefix_cache_bytes);
        metrics.prefix_evictions.set(xs.prefix_evictions);
        match tick_result {
            Ok(finished) => {
                metrics.ticks_total.inc();
                metrics.slot_busy_seconds.add_secs(t0.elapsed().as_secs_f64() * busy as f64);
                metrics.prefill_steps.add((sched.n_prefill - before.0) as u64);
                metrics.dual_steps.add((sched.n_dual - before.1) as u64);
                metrics.es_steps.add((sched.n_es - before.2) as u64);
                // publish the gauge before answering clients: a client that
                // just received its reply must not observe its own sequence
                // still counted as active (retirement already freed the slot,
                // so sched.active() is final here)
                guard.sync(sched.active());
                for f in finished {
                    metrics.retirements_total.inc();
                    metrics.tokens_generated.add(f.tokens as u64);
                    metrics.iterations_total.add(f.iterations as u64);
                    metrics.request_latency.observe_secs(f.queue_s + f.gen_s);
                    // per-class SLO attainment: TTFT from submit to the
                    // first committed block, TPOT over decoded positions
                    let ci = f.slo.index();
                    if let Some(ttft) = f.ttft_s {
                        metrics.class_ttft[ci].observe_secs(ttft);
                    }
                    if f.tokens > 0 && f.error.is_none() {
                        metrics.class_tpot[ci].observe_secs(f.gen_s / f.tokens as f64);
                    }
                    let reply = pending.remove(&f.id);
                    if let Some(err) = f.error {
                        // structured per-sequence failure (deadline
                        // overrun) — the rest of the group is untouched
                        if err.starts_with("timeout:") {
                            metrics.timeouts_total.inc();
                        }
                        if let Some(reply) = reply {
                            reply.put(Err(err));
                        }
                    } else if let Some(reply) = reply {
                        reply.put(Ok(GenReply {
                            text: f.text,
                            iterations: f.iterations,
                            wall_s: f.gen_s,
                            queue_s: f.queue_s,
                            tokens: f.tokens,
                        }));
                    }
                }
                recovery.consecutive_faults = 0;
                if recovery.quarantined {
                    recovery.clean_since_quarantine += 1;
                    if recovery.clean_since_quarantine >= REPROBE_AFTER {
                        // cool-down elapsed: re-probe the device-apply
                        // path; chains rebuild in the probed mode on the
                        // re-ground prefill
                        recovery.clean_since_quarantine = 0;
                        sched.set_apply_override(None);
                        match sched.reground_active() {
                            Ok(_) => {
                                recovery.quarantined = false;
                                if let Some(inj) = sched.fault_injector() {
                                    inj.note_chain_regrounded();
                                }
                                log::info!("re-probing device apply after quarantine cool-down");
                            }
                            Err(e) => {
                                // the probe itself faulted: stay in Host
                                // quarantine for another cool-down
                                log::warn!("device-apply re-probe failed: {e:#}");
                                sched.set_apply_override(Some(ApplyMode::Host));
                                if sched.reground_active().is_err() {
                                    fail_active(sched, pending, guard, &format!("{e}"));
                                    break false;
                                }
                            }
                        }
                    }
                }
                break true;
            }
            Err(e) => {
                let class = classify(&e);
                log::warn!("scheduler tick failed ({class:?}, attempt {attempt}): {e:#}");
                if class == TickErrorClass::Misconfig || attempt >= TICK_RETRY_BUDGET {
                    fail_active(sched, pending, guard, &format!("{e}"));
                    break false;
                }
                if class == TickErrorClass::Poisoned {
                    // a divergent fused dispatch cannot be trusted at this
                    // depth: step the ladder down before re-grounding
                    if let Some(k) = sched.demote_fused_k() {
                        if let Some(inj) = sched.fault_injector() {
                            inj.note_fused_k_demotion();
                        }
                        log::warn!("demoted fused dispatch depth to k={k}");
                    }
                }
                recovery.consecutive_faults += 1;
                if !recovery.quarantined && recovery.consecutive_faults >= QUARANTINE_AFTER {
                    sched.set_apply_override(Some(ApplyMode::Host));
                    recovery.quarantined = true;
                    recovery.clean_since_quarantine = 0;
                    if let Some(inj) = sched.fault_injector() {
                        inj.note_host_demotion();
                    }
                    log::warn!("quarantining device apply to Host after repeated faults");
                }
                // re-ground: one prefill over the occupied slots rebuilds
                // the device state from the (untouched) host trajectory,
                // so the retried tick is token-identical. The re-ground
                // itself may hit another injected fault — burn an attempt
                // and try again within the same budget.
                let mut grounded = false;
                while attempt <= TICK_RETRY_BUDGET {
                    match sched.reground_active() {
                        Ok(_) => {
                            if let Some(inj) = sched.fault_injector() {
                                inj.note_tick_retried();
                                inj.note_chain_regrounded();
                            }
                            grounded = true;
                            break;
                        }
                        Err(e2) if classify(&e2) != TickErrorClass::Misconfig => {
                            log::warn!("re-ground faulted (attempt {attempt}): {e2:#}");
                            attempt += 1;
                            recovery.consecutive_faults += 1;
                            std::thread::sleep(Duration::from_millis(1u64 << attempt.min(6)));
                        }
                        Err(e2) => {
                            log::error!("re-ground failed: {e2:#}");
                            break;
                        }
                    }
                }
                if !grounded {
                    fail_active(sched, pending, guard, &format!("{e}"));
                    break false;
                }
                std::thread::sleep(Duration::from_millis(1u64 << attempt.min(6)));
                attempt += 1;
            }
        }
    };
    pump_fault_stats(sched, metrics, recovery);
    outcome
}

fn admit_request(
    sched: &mut GroupScheduler<'_>,
    metrics: &Metrics,
    pending: &mut HashMap<u64, OneShot<Result<GenReply, String>>>,
    id: u64,
    req: GenRequest,
) {
    // deadline-aware admission: a request whose timeout_ms budget burned
    // away while it sat queued is shed right here, before a grounding
    // prefill is wasted on work nobody is waiting for anymore
    // (`timeout_ms: 0` falls through: the scheduler rejects it as a
    // bad request — an unmeetable deadline is a client error, not a shed)
    if let Some(ms) = req.params.timeout_ms {
        let waited_ms = req.submitted.elapsed().as_millis() as u64;
        if ms > 0 && waited_ms >= ms {
            metrics.timeouts_total.inc();
            metrics.shed_total.inc();
            req.reply.put(Err(format!(
                "timeout: exceeded {ms} ms after {waited_ms} ms queued (shed before prefill)"
            )));
            return;
        }
    }
    metrics.queue_latency.observe_secs(req.submitted.elapsed().as_secs_f64());
    let input = SeqInput {
        id,
        prompt: req.prompt,
        params: req.params,
        submitted: req.submitted,
    };
    match sched.admit(input) {
        Ok(_) => {
            metrics.admissions_total.inc();
            pending.insert(id, req.reply);
        }
        Err(e) => req.reply.put(Err(format!("{e}"))),
    }
}

/// Continuous batching: keep the slots hot — admit from the queue into
/// any free slot (newly admitted sequences get their grounding prefill
/// on the next tick), retire at block boundaries, repeat. Before each
/// admission round the batch class is resized to the demand (resident +
/// queued sequences) at block boundaries, parking/resuming retained
/// chains through the shared residency pool.
fn run_continuous(
    mut sched: GroupScheduler<'_>,
    queue: Arc<SloQueues>,
    metrics: Arc<Metrics>,
) {
    let mut pending = PendingRepliesGuard::new();
    let mut next_id: u64 = 0;
    let mut guard = ActiveSlotsGuard::new(metrics.clone());
    let mut recovery = RecoveryState::new();
    loop {
        // when idle, block for the first arrival and hold it so the
        // class can be sized to it before admission (a lone request
        // after a burst gets the b=1 executables). Parked victims count
        // as demand: with nothing active they resume below instead of
        // blocking here.
        let mut held: Option<GenRequest> = None;
        if sched.active() == 0 && sched.parked() == 0 {
            match queue.recv() {
                Some(r) => held = Some(r),
                None => return, // closed and drained
            }
        }
        // batch-class selection from demand, at block boundaries only
        let demand_queued = usize::from(held.is_some()) + queue.len() + sched.parked();
        if let Err(e) = sched.maybe_switch_class(demand_queued) {
            // the switch unwound to the outgoing class, but its chain may
            // have been lost mid-checkout: evict and re-ground explicitly
            // so resident sequences keep decoding instead of hitting an
            // unseeded chain on the next tick
            log::error!("batch-class switch failed: {e:#} — re-grounding the active class");
            match sched.reground_active() {
                Ok(n) => {
                    if let Some(inj) = sched.fault_injector() {
                        inj.note_chain_regrounded();
                    }
                    log::warn!("re-grounded {n} resident sequences after failed class switch");
                }
                Err(e2) => {
                    log::error!("re-ground after failed class switch also failed: {e2:#}");
                    fail_active(&mut sched, &mut pending, &mut guard, &format!("{e2}"));
                }
            }
            pump_fault_stats(&sched, &metrics, &mut recovery);
        }
        // resume parked preemption victims into free slots while no
        // waiting request outranks them (pressure dropped). Their next
        // plan is a grounding prefill off the preserved host trajectory,
        // so the resumed decode is token-identical.
        while sched.free_slots() > 0 {
            let Some(best) = sched.best_parked_class() else { break };
            let waiting = held
                .as_ref()
                .map(|r| r.params.slo)
                .into_iter()
                .chain(queue.peek_class())
                .min();
            if waiting.is_some_and(|qc| qc < best) {
                break;
            }
            match sched.resume_victim() {
                ResumeOutcome::Seated(_) => {}
                ResumeOutcome::Shed(f) => {
                    // the victim's deadline expired while parked: shed it
                    // with the structured timeout instead of re-seating
                    metrics.retirements_total.inc();
                    metrics.timeouts_total.inc();
                    metrics.shed_total.inc();
                    if let Some(reply) = pending.remove(&f.id) {
                        reply.put(Err(f
                            .error
                            .unwrap_or_else(|| "timeout: parked past deadline".to_string())));
                    }
                }
                ResumeOutcome::None => break,
            }
        }
        // admission: the held request first, then fill free slots.
        // (a failed admission — bad request — leaves the group idle, so
        // the loop circles back into the blocking recv)
        if let Some(req) = held.take() {
            let id = next_id;
            next_id += 1;
            admit_request(&mut sched, &metrics, &mut pending, id, req);
        }
        while sched.free_slots() > 0 {
            let req = match queue.try_recv() {
                Some(r) => r,
                None => break,
            };
            let id = next_id;
            next_id += 1;
            admit_request(&mut sched, &metrics, &mut pending, id, req);
        }
        // preemption: a queued arrival that outranks a resident sequence
        // and finds no free slot claims a victim's slot at the block
        // boundary (SLO-aware policy only; FIFO is the no-preemption
        // baseline). The victim parks trajectory-exact and resumes above
        // once pressure drops.
        if queue.policy == SloPolicy::SloAware {
            while sched.free_slots() == 0 {
                let Some(waiter) = queue.peek_class() else { break };
                if sched.preempt_victim(waiter).is_none() {
                    break;
                }
                let Some(req) = queue.try_recv() else { break };
                let id = next_id;
                next_id += 1;
                admit_request(&mut sched, &metrics, &mut pending, id, req);
            }
        }
        guard.sync(sched.active());
        // nothing admitted (e.g. the held request was a bad request):
        // don't charge an empty tick to the per-tick metrics — circle
        // back into the blocking recv instead, as the pre-pool loop did
        if sched.active() > 0 {
            tick_once(&mut sched, &metrics, &mut pending, &mut guard, &mut recovery);
        }
    }
}

/// [`crate::batcher::next_batch`] over the multi-lane queue: block for
/// the first request, then fill the batch within the flush window.
fn next_batch_slo(queue: &SloQueues, cfg: &BatcherCfg) -> Option<Vec<GenRequest>> {
    let first = queue.recv()?;
    let mut batch = vec![first];
    let deadline = Instant::now() + Duration::from_millis(cfg.flush_ms);
    while batch.len() < cfg.max_batch.max(1) {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match queue.recv_timeout(deadline - now) {
            Some(r) => batch.push(r),
            None => break,
        }
    }
    Some(batch)
}

/// Legacy baseline: drain a batch from the queue, run the whole group to
/// completion with no mid-flight admission, reply, repeat.
fn run_to_completion(
    mut sched: GroupScheduler<'_>,
    queue: Arc<SloQueues>,
    metrics: Arc<Metrics>,
    batcher: BatcherCfg,
) {
    let mut next_id: u64 = 0;
    let mut guard = ActiveSlotsGuard::new(metrics.clone());
    let mut recovery = RecoveryState::new();
    while let Some(batch) = next_batch_slo(&queue, &batcher) {
        metrics.batches_total.inc();
        metrics.batch_occupancy_sum.add(batch.len() as u64);
        let mut pending = PendingRepliesGuard::new();
        for req in batch {
            let id = next_id;
            next_id += 1;
            admit_request(&mut sched, &metrics, &mut pending, id, req);
        }
        guard.sync(sched.active());
        while sched.active() > 0 {
            if !tick_once(&mut sched, &metrics, &mut pending, &mut guard, &mut recovery) {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oneshot_roundtrip() {
        let s: OneShot<u32> = OneShot::new();
        let s2 = s.clone();
        std::thread::spawn(move || s2.put(7));
        assert_eq!(s.wait(), 7);
    }

    #[test]
    fn oneshot_wait_timeout_times_out_then_delivers() {
        // an unanswered oneshot times out instead of hanging forever …
        let s: OneShot<u32> = OneShot::new();
        let t0 = Instant::now();
        assert_eq!(s.wait_timeout(Duration::from_millis(10)), None);
        assert!(t0.elapsed() >= Duration::from_millis(10));
        // … and a delivered value still comes through within the bound
        let s2 = s.clone();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            s2.put(9);
        });
        assert_eq!(s.wait_timeout(Duration::from_secs(5)), Some(9));
    }

    fn sim_router(mode: SchedMode, slots: usize, queue_cap: usize) -> Router {
        let mut cfg = RouterCfg::new(
            EngineCfg::new("llada-nano", crate::engine::Method::EsDllm),
            std::path::PathBuf::from("/nonexistent"),
        );
        cfg.backend = WorkerBackend::Sim(SimCfg::default());
        cfg.batcher = BatcherCfg { max_batch: slots, flush_ms: 2 };
        cfg.queue_cap = queue_cap;
        cfg.mode = mode;
        Router::start(cfg)
    }

    #[test]
    fn continuous_router_serves_requests_end_to_end() {
        let router = sim_router(SchedMode::Continuous, 2, 16);
        let slot = router.submit("1+2=".into(), SeqParams::default()).unwrap();
        let reply = slot.wait().expect("sim generation succeeds");
        assert_eq!(reply.text, "1+2=", "sim echoes the prompt");
        assert!(reply.iterations > 0);
        assert!(reply.tokens > 0);
        // the resident-cache ledger reached the serving metrics: one
        // residency seed, then steady-state steps reuse the device copy
        assert!(router.metrics.upload_bytes.get() > 0);
        assert_eq!(router.metrics.full_kv_uploads.get(), 1);
        assert!(router.metrics.upload_bytes_saved.get() > 0);
        assert!(router.metrics.resident_reuses.get() > 0);
        // device-apply accounting flows through per tick: steps chained
        // the retained kv/ind/conf outputs and computed conf in-graph
        assert!(router.metrics.retained_out_reuses.get() > 0);
        assert!(router.metrics.d2h_bytes_avoided.get() > 0);
        assert!(router.metrics.ingraph_conf_steps.get() > 0);
        // the sliced downlink + donation ledger flows through too: runs
        // downloaded gen-region logit rows (saving the prompt-region
        // slice) with their chained inputs donated in place
        assert!(router.metrics.d2h_bytes_shipped.get() > 0);
        assert!(router.metrics.d2h_bytes_saved.get() > 0);
        assert!(router.metrics.donated_execs.get() > 0);
        // the pooled-residency gauges are pumped per tick: at least the
        // class serving this request is a live resident chain
        assert!(router.metrics.resident_chains.get() >= 1);
        router.shutdown();
    }

    #[test]
    fn fused_dispatch_counters_reach_the_metrics() {
        // fused_k > 1 turns runs of consecutive ES iterations into
        // k-step dispatches; the ledger's fused counters must flow
        // through tick_once into the serving metrics, and the decoded
        // text must stay exactly what the unfused path produces
        let mut cfg = RouterCfg::new(
            EngineCfg::new("llada-nano", crate::engine::Method::EsDllm),
            std::path::PathBuf::from("/nonexistent"),
        );
        cfg.engine.fused_k = 4;
        cfg.backend = WorkerBackend::Sim(SimCfg::default());
        cfg.batcher = BatcherCfg { max_batch: 2, flush_ms: 2 };
        cfg.queue_cap = 16;
        cfg.mode = SchedMode::Continuous;
        let router = Router::start(cfg);
        let slot = router.submit("1+2=".into(), SeqParams::default()).unwrap();
        let reply = slot.wait().expect("sim generation succeeds");
        assert_eq!(reply.text, "1+2=", "fused decode is trajectory-exact");
        let m = &router.metrics;
        assert!(m.fused_execs.get() > 0, "fused dispatches ran");
        assert!(
            m.inner_iters_fused.get() >= 2 * m.fused_execs.get(),
            "each fused dispatch advanced at least 2 iterations"
        );
        assert_eq!(
            m.dispatches_avoided.get(),
            m.inner_iters_fused.get() - m.fused_execs.get(),
            "every fused iteration past the first avoided one dispatch"
        );
        router.shutdown();
    }

    #[test]
    fn active_slots_guard_publishes_final_delta_on_panic() {
        // regression: a worker that panicked (or returned early) used to
        // leave its last active-slot delta in the shared gauge forever;
        // the drop-guard must take the contribution back during unwind
        let metrics = Arc::new(Metrics::default());
        let m2 = metrics.clone();
        let worker = std::thread::spawn(move || {
            let mut guard = ActiveSlotsGuard::new(m2);
            guard.sync(3);
            assert_eq!(guard.metrics.active_slots.get(), 3);
            panic!("worker dies mid-flight with occupied slots");
        });
        assert!(worker.join().is_err(), "the worker must have panicked");
        assert_eq!(
            metrics.active_slots.get(),
            0,
            "a dead worker must not inflate the gauge"
        );

        // the sync path still publishes plain deltas while alive
        let mut guard = ActiveSlotsGuard::new(metrics.clone());
        guard.sync(2);
        guard.sync(1);
        assert_eq!(metrics.active_slots.get(), 1);
        drop(guard);
        assert_eq!(metrics.active_slots.get(), 0, "clean exit drains too");
    }

    #[test]
    fn pending_replies_guard_answers_outstanding_oneshots_on_panic() {
        // regression: a worker that panicked between admission and reply
        // used to leave the client blocked on wait() forever; the
        // drop-guard must answer every outstanding oneshot during unwind
        let slot: OneShot<Result<GenReply, String>> = OneShot::new();
        let s2 = slot.clone();
        let worker = std::thread::spawn(move || {
            let mut pending = PendingRepliesGuard::new();
            pending.insert(7, s2);
            panic!("worker dies with replies in flight");
        });
        assert!(worker.join().is_err(), "the worker must have panicked");
        let err = slot.wait().unwrap_err();
        assert!(err.contains("worker terminated"), "{err}");

        // a reply delivered before the unwind is not overwritten
        let answered: OneShot<Result<GenReply, String>> = OneShot::new();
        {
            let mut pending = PendingRepliesGuard::new();
            pending.insert(1, answered.clone());
            let reply = pending.remove(&1).unwrap();
            reply.put(Err("bad request: x".into()));
        }
        assert_eq!(answered.wait().unwrap_err(), "bad request: x");
    }

    fn faulted_sim_router(plan: &str, slots: usize) -> Router {
        let mut cfg = RouterCfg::new(
            EngineCfg::new("llada-nano", crate::engine::Method::EsDllm),
            std::path::PathBuf::from("/nonexistent"),
        );
        cfg.engine.fault_plan = crate::fault::FaultPlan::parse(plan).unwrap();
        cfg.backend = WorkerBackend::Sim(SimCfg::default());
        cfg.batcher = BatcherCfg { max_batch: slots, flush_ms: 2 };
        cfg.queue_cap = 16;
        cfg.mode = SchedMode::Continuous;
        Router::start(cfg)
    }

    #[test]
    fn transient_exec_fault_recovers_token_identical_through_the_router() {
        // fault-free baseline
        let clean = sim_router(SchedMode::Continuous, 2, 16);
        let want = clean.submit("1+2=".into(), SeqParams::default()).unwrap();
        let want = want.wait().expect("fault-free run");
        clean.shutdown();

        // event 1 is the grounding prefill run; event 2 is the first step
        // run — fault it, and the recovery loop must re-ground and retry
        // to a token-identical completion (the --fault-plan path through
        // EngineCfg also covers the plan hand-off to sim workers)
        let router = faulted_sim_router("exec@2", 2);
        let slot = router.submit("1+2=".into(), SeqParams::default()).unwrap();
        let reply = slot.wait().expect("faulted run recovers");
        assert_eq!(reply.text, want.text, "recovery is token-identical");
        assert_eq!(reply.tokens, want.tokens);
        let m = &router.metrics;
        assert_eq!(m.faults_injected.get(), 1);
        assert_eq!(m.ticks_retried.get(), 1);
        assert!(m.chains_regrounded.get() >= 1);
        assert_eq!(m.requests_failed.get(), 0, "nobody saw the fault");
        router.shutdown();
    }

    #[test]
    fn retry_budget_exhaustion_fails_only_the_affected_sequence() {
        // five consecutive exec faults: the faulted tick (event 2) plus
        // every re-ground prefill (events 3-6) — the retry budget (3)
        // exhausts and the resident sequence fails with the typed fault
        let router = faulted_sim_router("exec@2,exec@3,exec@4,exec@5,exec@6", 1);
        let doomed = router.submit("ab".into(), SeqParams::default()).unwrap();
        let ok = router.submit("cdef".into(), SeqParams::default()).unwrap();
        let err = doomed.wait().unwrap_err();
        assert!(err.contains("injected exec fault"), "{err}");
        // the queued request was never touched by the fault: the worker
        // stays alive and serves it cleanly after the eviction
        assert_eq!(ok.wait().expect("unaffected request").text, "cdef");
        let m = &router.metrics;
        assert_eq!(m.requests_failed.get(), 1, "exactly the doomed sequence");
        assert_eq!(m.faults_injected.get(), 5);
        assert!(m.host_demotions.get() >= 1, "the fault streak quarantined to Host");
        router.shutdown();
    }

    #[test]
    fn failed_class_switch_regrounds_instead_of_limping_on() {
        // regression: an alloc fault during the very first downshift
        // (8 → 1, empty pool, nothing evictable) fails
        // maybe_switch_class; the old code only logged the error and
        // limped on. The worker must recover explicitly — re-ground the
        // unwound class — and still serve the request.
        let router = faulted_sim_router("alloc@1", 8);
        let slot = router.submit("1+2=".into(), SeqParams::default()).unwrap();
        let reply = slot.wait().expect("request survives the failed switch");
        assert_eq!(reply.text, "1+2=");
        let m = &router.metrics;
        assert_eq!(m.faults_injected.get(), 1);
        assert!(m.chains_regrounded.get() >= 1, "explicit recovery ran");
        assert_eq!(m.requests_failed.get(), 0);
        // the worker is healthy for the next request
        let again = router.submit("xy".into(), SeqParams::default()).unwrap();
        assert_eq!(again.wait().unwrap().text, "xy");
        router.shutdown();
    }

    #[test]
    fn overdue_request_gets_a_structured_timeout_reply() {
        let mut cfg = RouterCfg::new(
            EngineCfg::new("llada-nano", crate::engine::Method::EsDllm),
            std::path::PathBuf::from("/nonexistent"),
        );
        // slow enough that the first block boundary lands past 1 ms
        cfg.backend = WorkerBackend::Sim(SimCfg::default().with_costs(2000, 1000, 1000));
        cfg.batcher = BatcherCfg { max_batch: 1, flush_ms: 2 };
        cfg.queue_cap = 8;
        cfg.mode = SchedMode::Continuous;
        let router = Router::start(cfg);
        let params = SeqParams { timeout_ms: Some(1), ..Default::default() };
        let err = router
            .submit("abcdefgh".into(), params)
            .unwrap()
            .wait()
            .unwrap_err();
        assert!(err.starts_with("timeout:"), "{err}");
        assert_eq!(router.metrics.timeouts_total.get(), 1);
        assert_eq!(router.metrics.requests_failed.get(), 0, "a timeout is not a fault");
        // the slot was freed: the worker serves the next request
        let ok = router.submit("ab".into(), SeqParams::default()).unwrap();
        assert_eq!(ok.wait().unwrap().text, "ab");
        router.shutdown();
    }

    #[test]
    fn lone_request_downshifts_and_burst_upshifts() {
        // continuous mode owns classes {1, 8}: a lone request is served
        // on the b=1 class, and a burst grows the class back — all
        // through the shared pool, with no full reseed on re-use
        let router = sim_router(SchedMode::Continuous, 8, 64);
        let reply = router.submit("ab".into(), SeqParams::default()).unwrap();
        reply.wait().expect("lone request served");
        // exactly one chain seeded so far (the b=1 class)
        assert_eq!(router.metrics.full_kv_uploads.get(), 1);
        // a burst: all eight in flight forces the full class
        let handles: Vec<_> = (0..8)
            .map(|_| router.submit("cdef".into(), SeqParams::default()).unwrap())
            .collect();
        for h in handles {
            h.wait().expect("burst request served");
        }
        assert!(
            router.metrics.chain_switches.get() >= 1,
            "the burst forced at least one class switch"
        );
        // at most one seed per class ever (1 and 8): the parked chains
        // were reused, not rebuilt
        assert!(router.metrics.full_kv_uploads.get() <= 2);
        // another lone request comes back to the parked b=1 chain
        let reply = router.submit("xy".into(), SeqParams::default()).unwrap();
        reply.wait().expect("second lone request served");
        assert!(router.metrics.full_kv_uploads.get() <= 2, "no reseed on re-use");
        assert!(router.metrics.resident_chains.get() >= 1);
        router.shutdown();
    }

    #[test]
    fn run_to_completion_router_still_works() {
        let router = sim_router(SchedMode::RunToCompletion, 2, 16);
        let a = router.submit("ab".into(), SeqParams::default()).unwrap();
        let b = router.submit("cdef".into(), SeqParams::default()).unwrap();
        assert_eq!(a.wait().unwrap().text, "ab");
        assert_eq!(b.wait().unwrap().text, "cdef");
        router.shutdown();
    }

    #[test]
    fn invalid_params_fail_the_request_not_the_worker() {
        let router = sim_router(SchedMode::Continuous, 1, 8);
        let bad = SeqParams { gen_len: Some(3), ..Default::default() };
        let err = router.submit("ab".into(), bad).unwrap().wait().unwrap_err();
        assert!(err.starts_with("bad request:"), "{err}");
        // the worker must still be alive for the next request
        let ok = router.submit("ok".into(), SeqParams::default()).unwrap();
        assert_eq!(ok.wait().unwrap().text, "ok");
        router.shutdown();
    }

    /// Slow sim router: every step costs real microseconds, so a first
    /// request holds its slot long enough for queue pressure to build.
    fn slow_sim_router(slots: usize, queue_cap: usize, policy: SloPolicy) -> Router {
        let mut cfg = RouterCfg::new(
            EngineCfg::new("llada-nano", crate::engine::Method::EsDllm),
            std::path::PathBuf::from("/nonexistent"),
        );
        cfg.backend = WorkerBackend::Sim(SimCfg::default().with_costs(2000, 1000, 1000));
        cfg.batcher = BatcherCfg { max_batch: slots, flush_ms: 2 };
        cfg.queue_cap = queue_cap;
        cfg.mode = SchedMode::Continuous;
        cfg.policy = policy;
        Router::start(cfg)
    }

    #[test]
    fn request_expired_in_queue_is_shed_before_prefill() {
        // satellite: timeout_ms is enforced against total age at
        // admission — a request whose budget burned away while queued is
        // shed as `timeout:` without consuming a grounding prefill
        let router = slow_sim_router(1, 8, SloPolicy::SloAware);
        let long = router.submit("abcdefgh".into(), SeqParams::default()).unwrap();
        let doomed = SeqParams { timeout_ms: Some(1), ..Default::default() };
        let doomed = router.submit("cdef".into(), doomed).unwrap();
        let err = doomed.wait().unwrap_err();
        assert!(err.starts_with("timeout:"), "{err}");
        assert!(err.contains("shed before prefill"), "{err}");
        long.wait().expect("the resident request is untouched");
        let m = &router.metrics;
        assert_eq!(m.timeouts_total.get(), 1);
        assert_eq!(m.shed_total.get(), 1);
        // only the long request ever occupied a slot
        assert_eq!(m.admissions_total.get(), 1);
        router.shutdown();
    }

    #[test]
    fn overload_sheds_lowest_class_with_structured_errors() {
        // one slot, queue capacity two: a long throughput request holds
        // the slot while the queue fills with batch-class work
        let router = slow_sim_router(1, 2, SloPolicy::SloAware);
        let batch_params = SeqParams { slo: SloClass::Batch, ..Default::default() };
        let long = router.submit("abcdefgh".into(), SeqParams::default()).unwrap();
        let b1 = router.submit("ab".into(), batch_params).unwrap();
        let b2 = router.submit("cd".into(), batch_params).unwrap();
        // queue is now full of batch work: a latency-sensitive arrival
        // sheds the newest batch victim and takes its place
        let ls_params = SeqParams { slo: SloClass::LatencySensitive, ..Default::default() };
        let ls = router.try_submit("1+2=".into(), ls_params).unwrap();
        let err = b2.wait().unwrap_err();
        assert!(err.starts_with("overloaded:"), "{err}");
        // a batch arrival outranks nothing queued: it is shed itself,
        // through the oneshot (never a silent drop, never a hang)
        let b3 = router.try_submit("ef".into(), batch_params).unwrap();
        let err = b3.wait().unwrap_err();
        assert!(err.starts_with("overloaded:"), "{err}");
        // the survivors are all served
        long.wait().expect("resident request served");
        ls.wait().expect("latency-sensitive request served");
        b1.wait().expect("first batch request served");
        let m = &router.metrics;
        assert_eq!(m.shed_total.get(), 2, "exactly the two sheds above");
        router.shutdown();
    }

    #[test]
    fn fifo_policy_keeps_plain_queue_full_backpressure() {
        // the FIFO baseline: no shedding — a full queue fails try_submit
        // with Err (HTTP 503), exactly the pre-SLO behavior
        let router = slow_sim_router(1, 1, SloPolicy::Fifo);
        let a = router.submit("abcdefgh".into(), SeqParams::default()).unwrap();
        let b = router.submit("ab".into(), SeqParams::default()).unwrap();
        // the worker holds `a`, the queue holds `b`: full
        assert!(router.try_submit("cd".into(), SeqParams::default()).is_err());
        assert_eq!(router.metrics.requests_rejected.get(), 1);
        assert_eq!(router.metrics.shed_total.get(), 0, "FIFO never sheds");
        a.wait().expect("first served");
        b.wait().expect("second served");
        router.shutdown();
    }

    #[test]
    fn latency_sensitive_preempts_and_victim_resumes_token_identical() {
        // baseline: the victim prompt alone, unpreempted
        let clean = sim_router(SchedMode::Continuous, 1, 16);
        let want = clean.submit("cdef".into(), SeqParams::default()).unwrap();
        let want = want.wait().expect("unpreempted run");
        clean.shutdown();

        // one slot: a throughput victim is mid-decode when a
        // latency-sensitive request arrives → block-boundary preemption
        let router = slow_sim_router(1, 8, SloPolicy::SloAware);
        let victim = router.submit("cdef".into(), SeqParams::default()).unwrap();
        std::thread::sleep(Duration::from_millis(5));
        let ls_params = SeqParams { slo: SloClass::LatencySensitive, ..Default::default() };
        let ls = router.submit("1+2=".into(), ls_params).unwrap();
        let ls_reply = ls.wait().expect("latency-sensitive request served");
        assert_eq!(ls_reply.text, "1+2=");
        let victim_reply = victim.wait().expect("victim resumes and completes");
        assert_eq!(victim_reply.text, want.text, "park/resume is trajectory-exact");
        assert_eq!(victim_reply.tokens, want.tokens);
        let m = &router.metrics;
        assert!(m.preemptions_total.get() >= 1, "the victim was parked");
        assert!(m.resumed_total.get() >= 1, "and later resumed");
        assert_eq!(m.victims_parked.get(), 0, "nobody left parked at the end");
        assert_eq!(m.requests_failed.get(), 0);
        router.shutdown();
    }

    #[test]
    fn aged_victim_outranks_sustained_ls_burst() {
        // starvation bound: under a sustained latency-sensitive burst a
        // parked throughput victim ages into the LS class, so it (a)
        // resumes ahead of the queued fresh LS arrivals at the first
        // free slot and (b) cannot be re-preempted by the rest of the
        // burst — parked exactly once, end to end token-identical
        let clean = sim_router(SchedMode::Continuous, 1, 16);
        let want = clean.submit("cdef".into(), SeqParams::default()).unwrap();
        let want = want.wait().expect("unpreempted run");
        clean.shutdown();

        let mut cfg = RouterCfg::new(
            EngineCfg::new("llada-nano", crate::engine::Method::EsDllm),
            std::path::PathBuf::from("/nonexistent"),
        );
        cfg.backend = WorkerBackend::Sim(SimCfg::default().with_costs(2000, 1000, 1000));
        cfg.batcher = BatcherCfg { max_batch: 1, flush_ms: 2 };
        cfg.queue_cap = 16;
        cfg.mode = SchedMode::Continuous;
        cfg.policy = SloPolicy::SloAware;
        // immediate promotion: one parked tick is enough to age to LS
        cfg.park_promote_ms = Some(0);
        let router = Router::start(cfg);

        let victim = router.submit("cdef".into(), SeqParams::default()).unwrap();
        std::thread::sleep(Duration::from_millis(5));
        let ls_params = SeqParams { slo: SloClass::LatencySensitive, ..Default::default() };
        let burst: Vec<_> = (0..4)
            .map(|_| router.submit("1+2=".into(), ls_params).unwrap())
            .collect();
        let victim_reply = victim.wait().expect("victim resumes and completes");
        assert_eq!(victim_reply.text, want.text, "aged resume is trajectory-exact");
        assert_eq!(victim_reply.tokens, want.tokens);
        for ls in burst {
            let r = ls.wait().expect("every burst request served");
            assert_eq!(r.text, "1+2=");
        }
        let m = &router.metrics;
        assert_eq!(
            m.preemptions_total.get(),
            1,
            "the aged victim was parked once and shielded thereafter"
        );
        assert_eq!(m.resumed_total.get(), 1);
        assert_eq!(m.victims_parked.get(), 0);
        assert_eq!(m.requests_failed.get(), 0);
        assert_eq!(m.timeouts_total.get(), 0);
        router.shutdown();
    }
}
