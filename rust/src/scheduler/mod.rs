//! Continuous batching: a slot-based group scheduler that admits and
//! retires sequences at block boundaries.
//!
//! The old engine ran every batch group in lockstep to completion: one
//! slow sequence held seven finished slots hostage, and arrivals waited
//! for the whole group to drain. This module decomposes that loop into
//!
//!   * [`SeqState`] — one sequence's decode state machine: its token
//!     row, current block index, iteration counters, per-request sampler
//!     and generation-length parameters, and completion bookkeeping;
//!   * [`GroupScheduler`] — owner of a fixed set of batch slots. Each
//!     [`GroupScheduler::tick`] steps every occupied slot one iteration:
//!     slots wanting a `Prefill` (block grounding / prompt refresh /
//!     vanilla) share one full forward whose outputs are merged into
//!     their rows only, and the remaining slots are grouped by
//!     (block index, step plan) so sequences at different blocks each
//!     get a step at their own window. After unmasking, slots whose
//!     block completed advance; sequences that are finished — every
//!     position unmasked, or an EOS with nothing masked before it (the
//!     EOS-guard early exit) — retire at that block boundary, freeing
//!     the slot for the next admission;
//!   * [`StepBackend`] — the executable plumbing behind a tick.
//!     [`PjrtBackend`] drives the real compiled artifacts;
//!     [`sim::SimBackend`] is a deterministic model-free substitute for
//!     tests and scheduler benchmarks.
//!
//! Correctness of mid-flight admission rests on two facts: batch rows
//! are independent sequences end to end (attention never crosses rows),
//! and every cache merge here is row-filtered (`*_slots` operations in
//! [`crate::cache`], or the in-graph `where(occ)` passthrough of the
//! device-apply executables), so a grounding prefill for a newly
//! admitted slot — or a step applied at another slot's block window —
//! never perturbs the other occupants' trajectories. Vacant rows are
//! additionally pinned to confidence -1 for the in-graph importance
//! selection: host-side on the masked confidence input of the stateless
//! step executables, in-graph from the batch-bit occupancy mask on the
//! device-apply ones.
//!
//! Step I/O is mediated by the resident-cache layer
//! ([`crate::runtime::resident::DeviceGroupCaches`]). On the device-
//! apply path (`ApplyMode::Device` — the PJRT backend whenever the
//! `*_apply` executables are compiled, and the sim backend by default)
//! the executables scatter their own cache updates in-graph, the
//! runtime retains those outputs (donating the chained inputs in place
//! under the manifest's input-output alias config), and the backend
//! chains them across ticks — steady state ships block tokens and
//! batch-bit masks up and gen-region logit rows down (`logits_gen`
//! `[B, gen, V]` for a grounding prefill, the `[B, k, V]` selected rows
//! plus positions for a step), nothing else. On the Host-apply fallback,
//! per-kind dirty bitmaps in [`crate::cache::GroupCaches`] track which
//! rows the host mutated since the device copy was refreshed and syncs
//! ship only those rows (admission invalidation re-syncs exactly the
//! admitted slot), with pooled staging buffers replacing the historical
//! per-tick host clones. The per-backend
//! [`crate::runtime::resident::TransferStats`] ledger flows through
//! [`GroupScheduler::transfer_stats`] into the serving metrics.
//!
//! # Fused k-step dispatches
//!
//! With `SchedCfg::k >= 2` (the `EngineCfg::fused_k` knob), runs of
//! consecutive ES iterations dispatch as ONE device execution:
//! [`StepBackend::run_step_fused`] runs a `step_apply_k` executable
//! that unrolls the diffusion loop in-graph — the HOST sampler rule
//! replicated between inner iterations (highest-confidence masked
//! block position, last max on ties, EOS guard, argmax token caches
//! seeded from the host logits mirror so rows the skip chain drops
//! still commit the host's token), confidence recomputed in-graph each
//! time, the retained kv/ind/conf chain threaded through the unrolled
//! body — and downlinks the FINAL iteration's selected logit rows plus
//! each inner iteration's committed position and token
//! (`commit_pos`/`commit_tok`) and a per-slot committed-count audit
//! vector. The scheduler applies the downlinked commits to its token
//! mirror DIRECTLY — it never re-derives them from the final
//! iteration's logits, which would desync the mirror whenever an
//! earlier iteration's commit changed the later ordering. The
//! scheduler chooses the fusible depth so trajectories stay exact vs
//! k = 1: a slot is eligible only under greedy sampling with the
//! default EOS guard (temperature ≤ 0, no parallel threshold, guard on
//! — the in-graph rule is exactly that sampler; exactly one commit per
//! inner iteration), the depth is capped at the refresh policy's
//! consecutive-ES run length (peeked via `plan_es`) and at the block's
//! remaining masked positions (so a block can complete only at the
//! final inner iteration), and a step group fuses at the minimum depth
//! over its members. The backend may fuse fewer iterations than
//! requested — it floors to the deepest compiled `es_applyk{K}`
//! variant ([`crate::engine::FUSED_KS`]) — or decline outright
//! (returns 0: Host apply mode, no fused executables), in which case
//! the tick falls back to the single-step path; the tail of a block
//! always runs on the k = 1 executables. Host-visible early exit —
//! EOS retirement and block-boundary admission — is checked once per
//! fused run rather than once per iteration: that coarser cadence is
//! what `k` trades for dispatch amortization (the remaining-masked cap
//! keeps the trade lossless: nothing retirable can appear before the
//! final inner iteration). One honest residual of the
//! final-iteration-only logits downlink: host logits/conf mirror rows
//! refreshed only by inner iterations 1..k−1 lag until the next
//! download touches them — harmless for decode (the commits themselves
//! ride the downlink) and refreshed wholesale by the next grounding
//! prefill.
//!
//! # Batch classes and pooled residency
//!
//! A scheduler can own several **batch classes** (e.g. b=1 and b=8 —
//! the shapes the executables are compiled for), each with its own slot
//! array, token buffer, and [`GroupCaches`]. At block boundaries —
//! the only points where every resident sequence's next plan is the
//! grounding prefill anyway, so moving it is trajectory-exact —
//! [`GroupScheduler::maybe_switch_class`] sizes the active class to the
//! demand (resident + queued sequences): a lone request after a burst
//! shrinks back to the latency-optimal b=1 executables, a deep queue
//! upshifts to the full batch. An optional [`SwitchHysteresis`] damps
//! the downshift side with an arrival-rate EWMA plus a post-switch
//! hold window (upshifts stay instant — capacity must react to load),
//! so a bursty trace stops thrashing the chain between classes. A switch parks the outgoing class's
//! retained chain in the shared
//! [`crate::runtime::resident::ResidencyPool`] and checks the incoming
//! class's chain back out, so batch-shape churn never pays a full KV
//! reseed: only slots dirtied since the chain was parked re-ship (and
//! under `ApplyMode::Device` even those regenerate on device through
//! the migrated sequences' grounding prefill). Multiple router workers
//! share one pool — PJRT workers park under their own owner id (PJRT
//! buffers are not `Send`), the sim backend parks under the shared
//! owner and models true cross-worker device sharing.
//!
//! # Fault tolerance
//!
//! A tick's backend work (phases 2–3: shared prefill, step groups)
//! surfaces errors with `?` BEFORE the unmask phase — the only place
//! the host trajectory mutates — so a failed tick leaves every
//! sequence's tokens exactly as they were and the next [`tick`]
//! re-plans it from scratch. That retry-safety invariant is what the
//! router's recovery loop builds on: it classifies the error with
//! [`crate::fault::classify`] (transient injected fault / poisoned
//! chain / misconfiguration), calls
//! [`GroupScheduler::reground_active`] — invalidate the active class's
//! resident device state, then one grounding prefill over every
//! occupied slot regenerates chain and logits/conf mirrors from the
//! host token mirror — and re-ticks within a bounded retry budget.
//! Recovered sequences produce token-identical output; unaffected
//! classes never notice. Poisoned-chain errors (the fused
//! committed-count audits here and in the backends, typed
//! [`crate::fault::PoisonedChain`]) additionally step the fused depth
//! down one rung ([`GroupScheduler::demote_fused_k`]) before the
//! retry, and repeated device faults quarantine the backend to
//! `ApplyMode::Host` via [`GroupScheduler::set_apply_override`] — both
//! rungs of the device→host degradation ladder, recorded in the
//! backend's [`crate::fault::FaultStats`] ledger. Sequences carrying a
//! [`SeqParams::timeout_ms`] deadline retire at their next block
//! boundary with a structured `timeout:` error once overdue
//! ([`FinishedSeq::error`]), never holding a slot past the cut point.
//!
//! # SLO classes and block-boundary preemption
//!
//! Every request carries a service class ([`SloClass`]:
//! `LatencySensitive`, `Throughput`, `Batch` — lower discriminant =
//! higher priority) from the `/generate` JSON body through
//! [`SeqParams::slo`] into its [`SeqState`]. The router's per-class
//! priority queues and load-shedding live in `router/`; what this
//! module contributes is **preemption at block boundaries**: when a
//! higher-class request is waiting and no slot is free,
//! [`GroupScheduler::preempt_victim`] lifts the lowest-priority seated
//! sequence whose class is strictly below the waiter's — provided that
//! victim sits at a block boundary (`i_b == 0`) — out of its slot,
//! parking its complete decode state (the [`SeqState`], including its
//! private sampling stream, plus its token row) beside the pooled
//! chains, and resets the slot for the preemptor.
//! [`GroupScheduler::resume_victim`] reseats the highest-priority
//! parked victim into a free slot when pressure drops. Both moves are
//! trajectory-exact for the same reason a batch-class switch is: at a
//! block boundary the sequence's next plan is the grounding prefill,
//! which regenerates its device rows and logits/conf mirrors from the
//! host token mirror, and every cache merge is row-filtered, so
//! neither the preemptor's arrival nor the victim's departure and
//! return perturbs any trajectory — a preempted-then-resumed sequence
//! decodes token-identically to an unpreempted run (asserted in the
//! scheduler tests and `tests/slo_serving.rs`, over the sim and the
//! PJRT-planner call sequence alike). A parked victim whose
//! `timeout_ms` deadline expires before a slot frees is shed at
//! resume time with the same structured `timeout:` error a seated
//! overdue sequence gets — parked state never strands a client.
//! Preemption events land in the shared pool ledger
//! ([`crate::runtime::resident::PoolStats`]) via
//! [`StepBackend::note_preempt`].
//!
//! # Live-context decoding
//!
//! With [`GroupScheduler::enable_live_ctx`] on, the decode hot path
//! scales with the **live** context instead of the compiled maximum.
//! The backend advertises a ladder of compiled context tiers
//! ([`StepBackend::ctx_tiers`] — the manifest's `generation.ctx_tiers`
//! family on PJRT, `SimCfg::ctx_tiers` on the sim), each a strictly
//! shorter key length the `step_apply` / `es_applyk*` executables were
//! also compiled at. At the top of every tick the scheduler computes
//! the group's **frontier** — the max over occupied slots of
//! `min(seq gen_len, (block_idx + 1) · block)` — and selects the
//! smallest tier ≥ `prompt_len + frontier`. Everything past that tier
//! is either a fully-decoded suffix block (every position committed;
//! attention over it cannot change any remaining commit under the
//! row-independent cache layout) or a block the per-request
//! [`SeqParams::gen_len`] guarantees will never be touched, so pruning
//! it from the attention context at a block boundary is
//! trajectory-exact: the pruned run decodes token-identically to the
//! full-context run (asserted for greedy, fused k ≥ 2, mid-flight
//! admission, and preempt/resume across a tier switch). A tier
//! *switch* forces one full-group grounding prefill at the new live
//! length — the same regrounding a batch-class switch pays, and legal
//! at the same points — so the effective batch class becomes
//! (batch, max-live-context). Sequences whose EOS guard fires before
//! their final block **retire early**: the trailing never-decoded
//! blocks are credited to the ledger via
//! [`StepBackend::note_early_retire`] without ever being dispatched.
//! The per-exec ledger (live vs full row·ticks, suffix blocks pruned,
//! early-retired blocks, tier switches, and an abstract
//! batch × rows × live-keys FLOPs estimate) flows through
//! [`crate::runtime::resident::TransferStats`] into the `/metrics`
//! gauges; the sim backend models the tiered planner byte-exactly, so
//! the sim-vs-PJRT ledger parity tests extend to pruned ticks.
//!
//! [`tick`]: GroupScheduler::tick
//!
//! One documented exception: the experimental adaptive skip-ratio mode
//! (`EngineCfg::adaptive`) keeps a single group-scoped confidence-drift
//! signal — as the pre-refactor engine did for its lockstep batch — so
//! under adaptive decoding the executable-variant choice, and therefore
//! a sequence's exact trajectory, can depend on co-resident traffic.
//! All production configurations (adaptive off) are fully isolated.

pub mod sim;

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::cache::{GroupCaches, RefreshPolicy, StepPlan};
use crate::engine::{
    apply_step_exe_name, device_apply_eligible, fused_step_exe_name, prefill_apply_blk_exe_name,
    prefill_apply_exe_name, step_exe_name, EngineCfg, Method, FUSED_KS,
};
use crate::fault::{FaultInjector, FaultKind, PoisonedChain};
use crate::manifest::{ArchSpec, Dims, DType, ExeKind};
use crate::rng::SplitMix;
use crate::runtime::resident::{
    chain_seed_bytes, ApplyMode, DeviceGroupCaches, PoolStats, PreemptEvent, PrefixCache,
    PrefixStats, ResidencyPool, SyncOutcome, TransferStats, UploadHandle,
};
use crate::runtime::tensor::HostTensor;
use crate::runtime::{ExecArg, Runtime};
use crate::sampler::{decide_unmask_with, SamplerCfg, SamplerScratch, UnmaskInput};
use crate::tokenizer::Tokenizer;

/// Service-level class of a request, carried from the `/generate` JSON
/// body (`"slo"`) through [`SeqParams`] into the router's priority
/// queues and the scheduler's preemption decisions. Lower discriminant
/// = higher priority, so the derived `Ord` ranks classes directly.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum SloClass {
    /// interactive traffic: jumps every queue, may preempt a seated
    /// lower-class sequence at a block boundary
    LatencySensitive = 0,
    /// the default class: ordinary traffic, preemptible by
    /// latency-sensitive arrivals
    #[default]
    Throughput = 1,
    /// offline/bulk traffic: first to be load-shed under overload,
    /// first to be preempted
    Batch = 2,
}

impl SloClass {
    /// Number of classes (sizes the per-class metric arrays).
    pub const COUNT: usize = 3;
    /// Every class, in priority order.
    pub const ALL: [SloClass; SloClass::COUNT] =
        [SloClass::LatencySensitive, SloClass::Throughput, SloClass::Batch];

    /// Parse the `/generate` JSON field. Accepts the canonical names
    /// plus the common short form for the interactive class.
    pub fn parse(s: &str) -> Option<SloClass> {
        match s {
            "latency_sensitive" | "latency" => Some(SloClass::LatencySensitive),
            "throughput" => Some(SloClass::Throughput),
            "batch" => Some(SloClass::Batch),
            _ => None,
        }
    }

    /// Canonical name (metric labels, error messages).
    pub fn name(self) -> &'static str {
        match self {
            SloClass::LatencySensitive => "latency_sensitive",
            SloClass::Throughput => "throughput",
            SloClass::Batch => "batch",
        }
    }

    /// Index into per-class arrays (priority order).
    pub fn index(self) -> usize {
        self as usize
    }

    /// This class promoted `levels` priority levels (saturating at
    /// [`SloClass::LatencySensitive`]) — the starvation bound's aging
    /// ladder for long-parked preemption victims.
    pub fn promote(self, levels: usize) -> SloClass {
        SloClass::ALL[self.index().saturating_sub(levels)]
    }
}

/// Effective service class of a sequence that has spent `credit` time
/// parked off its slot: one priority level per elapsed `promote`
/// interval. `None` disables aging (the effective class is the base
/// class forever — the unbounded-starvation baseline).
fn aged_class(base: SloClass, credit: Duration, promote: Option<Duration>) -> SloClass {
    let Some(p) = promote else { return base };
    if credit.is_zero() {
        // a sequence that was never parked keeps its base class no
        // matter the interval (a zero interval must not make every
        // seated sequence unpreemptable)
        return base;
    }
    if p.is_zero() {
        base.promote(SloClass::COUNT)
    } else {
        base.promote((credit.as_nanos() / p.as_nanos()) as usize)
    }
}

/// Per-request generation parameters carried from the `/generate` JSON
/// body into the sequence state machine. `None` means "use the server
/// default".
#[derive(Debug, Clone, Copy, Default)]
pub struct SeqParams {
    /// requested generation length (multiple of the block length,
    /// at most the compiled gen region)
    pub gen_len: Option<usize>,
    /// sampling temperature override
    pub temperature: Option<f32>,
    /// confidence-aware parallel-decoding threshold override
    pub parallel_threshold: Option<f32>,
    /// per-request deadline, measured from submission. An overdue
    /// sequence retires at its next block boundary with a structured
    /// `timeout:` error instead of its text (the server maps it to 504,
    /// never a blanket 500); a request already overdue at admission is
    /// shed before its grounding prefill is ever scheduled.
    pub timeout_ms: Option<u64>,
    /// service class (priority-queue lane, shed order, preemption
    /// rank); defaults to [`SloClass::Throughput`]
    pub slo: SloClass,
}

/// A sequence waiting to enter a slot.
#[derive(Debug, Clone)]
pub struct SeqInput {
    pub id: u64,
    pub prompt: String,
    pub params: SeqParams,
    pub submitted: Instant,
}

/// One slot's resident sequence: the per-sequence state machine.
#[derive(Debug, Clone)]
pub struct SeqState {
    pub id: u64,
    /// effective generation length (≤ compiled gen region)
    pub gen_len: usize,
    pub sampler: SamplerCfg,
    /// per-sequence sampling stream, seeded from (scheduler seed,
    /// request id): sampled decoding (temperature > 0) must not depend
    /// on which other sequences happen to be co-resident
    rng: SplitMix,
    /// current block within this sequence's own gen region
    pub block_idx: usize,
    /// iteration within the current block (drives the refresh policy)
    pub i_b: usize,
    /// total iterations this sequence has been stepped
    pub iters: usize,
    pub n_prefill: usize,
    pub n_dual: usize,
    pub n_es: usize,
    pub submitted: Instant,
    pub admitted: Instant,
    /// per-request deadline measured from `submitted` (see
    /// [`SeqParams::timeout_ms`])
    pub timeout_ms: Option<u64>,
    /// service class (drives preemption eligibility: a seated sequence
    /// is preemptible by any strictly-higher-class waiter)
    pub slo: SloClass,
    /// when the first token committed to this sequence's mirror (TTFT
    /// numerator; `None` until the first unmask decision lands)
    pub first_commit: Option<Instant>,
    /// total time this sequence has spent parked off its slot as a
    /// preemption victim. Feeds the aging ladder: the effective class
    /// rises one level per [`GroupScheduler::set_park_promote`]
    /// interval, so a reseated long-parked victim cannot be re-preempted
    /// by the same burst that parked it (the starvation bound).
    pub park_credit: Duration,
}

/// A retired sequence with its true per-request statistics (these
/// replace the old group-level reply).
#[derive(Debug, Clone)]
pub struct FinishedSeq {
    pub id: u64,
    pub text: String,
    /// iterations this sequence was stepped (not the group total)
    pub iterations: usize,
    /// positions actually decoded — answer content plus EOS fill, i.e.
    /// the unmasked prefix of the gen region (≤ gen_len when the EOS
    /// guard retired the sequence early; each counted position cost
    /// decode compute, so this is the honest throughput numerator)
    pub tokens: usize,
    pub n_prefill: usize,
    pub n_dual: usize,
    pub n_es: usize,
    /// submit → admission (queue time)
    pub queue_s: f64,
    /// admission → retirement (generation time)
    pub gen_s: f64,
    /// structured retirement error (e.g. `timeout: …`): the sequence
    /// retired without a usable completion and the router must deliver
    /// this message instead of `text`
    pub error: Option<String>,
    /// service class (routes the latency observations into the
    /// per-class TTFT/TPOT histograms)
    pub slo: SloClass,
    /// submission → first committed token (time-to-first-token; `None`
    /// when the sequence retired before any commit)
    pub ttft_s: Option<f64>,
}

/// Per-slot commit transcript of a fused run: for each member of the
/// dispatched `slots` (same order), the inner iterations' committed
/// `(gen position, token)` pairs in commit order — one pair per fused
/// iteration under the greedy eligibility gate.
pub type FusedCommits = Vec<Vec<(usize, i32)>>;

/// The executable plumbing behind one scheduler tick. Implementations
/// must merge results for the given `slots` rows only; spectator rows'
/// outputs are garbage by contract and must be discarded.
pub trait StepBackend {
    fn dims(&self) -> &Dims;
    fn tokenizer(&self) -> &Tokenizer;
    /// Full forward over `[B, ctx]` tokens; refresh the given slots'
    /// caches (or, for the vanilla method, only their logits state).
    fn run_prefill(
        &mut self,
        tokens: &[i32],
        slots: &[usize],
        caches: &mut GroupCaches,
    ) -> Result<()>;
    /// One block step (`DualStep` or `EsStep`) over `block` positions at
    /// `block_start`, merged into the given slots' rows only.
    fn run_step(
        &mut self,
        plan: StepPlan,
        tokens: &[i32],
        block_start: usize,
        block: usize,
        slots: &[usize],
        caches: &mut GroupCaches,
    ) -> Result<()>;
    /// Run `k` consecutive ES iterations over `block` positions at
    /// `block_start` as ONE fused device execution, merging the FINAL
    /// iteration's results into the given slots' rows. Returns how many
    /// iterations were actually fused plus, per member of `slots` (same
    /// order), the inner iterations' committed `(gen position, token)`
    /// pairs in commit order — the device picked them with the host
    /// sampler rule replicated in-graph, and the scheduler applies them
    /// to its token mirror verbatim (replaying against the single
    /// downlinked final-iteration logits would diverge whenever an
    /// earlier commit reorders the later iterations). A fused count of
    /// 0 means "not supported here" (no fused executables, Host apply
    /// mode; a backend may also floor `k` to its deepest compiled
    /// unroll depth) — the scheduler then falls back to
    /// [`StepBackend::run_step`]. The caller guarantees every slot
    /// decodes greedily with the default EOS guard (exactly one commit
    /// per iteration, the in-graph rule) and has at least `k` masked
    /// positions and consecutive ES plans ahead.
    fn run_step_fused(
        &mut self,
        _tokens: &[i32],
        _block_start: usize,
        _block: usize,
        _k: usize,
        _slots: &[usize],
        _caches: &mut GroupCaches,
    ) -> Result<(usize, FusedCommits)> {
        Ok((0, FusedCommits::new()))
    }
    /// Cumulative host→device transfer ledger for this backend (logical
    /// bytes from the resident-cache planner; zeros for backends without
    /// one).
    fn transfer_stats(&self) -> TransferStats {
        TransferStats::default()
    }
    /// Drop the resident device state of `caches`' batch class (retained
    /// handles, seeded chain, and the pooled entry) and mark the host
    /// caches fully dirty. Called by [`GroupScheduler::evict_all`] so a
    /// later re-admission can never step against a stale device copy of
    /// the evicted group.
    fn invalidate_resident(&mut self, _caches: &mut GroupCaches) {}
    /// Park the resident chain of `caches`' batch class in the shared
    /// residency pool (the scheduler is switching away from this class).
    /// No-op for backends without a resident layer.
    fn park_chain(&mut self, _caches: &mut GroupCaches) {}
    /// Activate the resident chain for `caches`' batch class: check a
    /// parked chain back out of the pool, or register a fresh one. The
    /// backends also run this lazily on their first prefill/step for a
    /// class, so single-class callers never need to call it.
    fn checkout_chain(&mut self, _caches: &mut GroupCaches) -> Result<()> {
        Ok(())
    }
    /// Count one batch-class switch in the pool ledger.
    fn note_chain_switch(&self) {}
    /// Record a preemption-ledger event (victim parked / resumed /
    /// dropped) in the shared residency pool — the parked-victim slot
    /// state lives beside the pooled chains in that ledger. No-op for
    /// backends without a pool.
    fn note_preempt(&self, _ev: PreemptEvent) {}
    /// Cumulative residency-pool ledger (zeros for backends without one).
    fn pool_stats(&self) -> PoolStats {
        PoolStats::default()
    }
    /// Probe the shared cross-request prefix cache for the longest
    /// block-aligned cached prefix of `content` (an admitted prompt's
    /// tokens, padding stripped). A hit returns the prefix length and a
    /// clone of the cached prompt-region KV rows
    /// ([`GroupCaches::merge_prefix_rows`] layout) and credits the
    /// skipped prefill bytes to the [`PrefixStats`] ledger. `None` for
    /// backends without a cache (every admission then pays the full
    /// grounding prefill, exactly as before).
    fn prefix_probe(
        &mut self,
        _content: &[i32],
        _block: usize,
        _caches: &GroupCaches,
    ) -> Option<(usize, Vec<u16>)> {
        None
    }
    /// Offer a retiring slot's longest block-aligned prompt prefix to
    /// the shared cross-request cache (insert-on-retire). No-op for
    /// backends without a cache.
    fn prefix_offer(
        &mut self,
        _content: &[i32],
        _block: usize,
        _caches: &GroupCaches,
        _slot: usize,
    ) {
    }
    /// Cumulative prefix-cache ledger (zeros for backends without one).
    fn prefix_stats(&self) -> PrefixStats {
        PrefixStats::default()
    }
    /// The backend's fault injector — the home of its
    /// [`crate::fault::FaultStats`] ledger. `None` for backends without
    /// fault modeling.
    fn fault_injector(&self) -> Option<Arc<FaultInjector>> {
        None
    }
    /// Recovery-ladder override of the backend's apply mode: `Some(Host)`
    /// quarantines the device-apply path after repeated device faults,
    /// `None` re-probes back. Implementations retire their resident
    /// layers so chains rebuild in the new mode; the caller re-grounds
    /// afterwards. No-op for backends without a resident layer.
    fn set_apply_override(&mut self, _mode: Option<ApplyMode>) {}
    /// Live-context tiers this backend can execute at, ascending and
    /// ending at the full compiled context (`manifest.ctx_tiers`). The
    /// default — just the full context — makes tiering a no-op for
    /// backends without tiered executables.
    fn ctx_tiers(&self) -> Vec<usize> {
        vec![self.dims().ctx]
    }
    /// Target live-context rows for subsequent dispatches (a value from
    /// [`StepBackend::ctx_tiers`]). Backends apply it to their resident
    /// planner at the next run; the scheduler forces a grounding prefill
    /// on every tier change, which rebuilds the retained chain at the
    /// new shapes in-graph. No-op for backends without a resident layer.
    fn set_live_ctx(&mut self, _rows: usize) {}
    /// Ledger-only: count `blocks` trailing gen blocks a retiring
    /// sequence never decoded (EOS-guard completion before its
    /// `gen_len`). No-op for backends without a transfer ledger.
    fn note_early_retire(&mut self, _caches: &mut GroupCaches, _blocks: u64) {}
    /// Block-sliced grounding prefill: like [`StepBackend::run_prefill`],
    /// but the host downlink is each refreshed slot's CURRENT block
    /// window — `[B, block, V]` instead of the whole gen region —
    /// selected in-graph by the `block_starts` uplink (batch-indexed,
    /// gen-relative; don't-care for slots outside the refresh set). The
    /// default delegates to the full-region prefill, so the sliced
    /// downlink is purely an optimization backends opt into.
    fn run_prefill_blk(
        &mut self,
        tokens: &[i32],
        slots: &[usize],
        _block_starts: &[usize],
        _block: usize,
        caches: &mut GroupCaches,
    ) -> Result<()> {
        self.run_prefill(tokens, slots, caches)
    }
}

/// Batch-class switch damping for
/// [`GroupScheduler::maybe_switch_class`]: an EWMA over the demand
/// samples argues against downshifts (the smoothed signal remembers a
/// burst after its instantaneous tail), and a hold window after each
/// switch suppresses downshifts outright. Upshifts always pass —
/// capacity must react to load immediately.
#[derive(Debug, Clone, Copy)]
pub struct SwitchHysteresis {
    /// EWMA smoothing factor for the demand samples (0 < alpha ≤ 1;
    /// smaller = longer memory of a burst)
    pub alpha: f64,
    /// demand evaluations after a switch during which downshifts are
    /// suppressed
    pub hold: usize,
}

impl Default for SwitchHysteresis {
    fn default() -> SwitchHysteresis {
        SwitchHysteresis { alpha: 0.25, hold: 8 }
    }
}

/// Scheduling parameters (the method-level subset of [`EngineCfg`]).
#[derive(Debug, Clone)]
pub struct SchedCfg {
    pub method: Method,
    pub block: usize,
    pub refresh: RefreshPolicy,
    pub sampler: SamplerCfg,
    pub seed: u64,
    /// fused-step unroll depth: runs of consecutive ES iterations
    /// dispatch as one `step_apply_k` execution up to this depth
    /// (1 = unfused; see the module docs)
    pub k: usize,
    /// batch-class switch damping; `None` switches on the
    /// instantaneous demand with no memory
    pub hysteresis: Option<SwitchHysteresis>,
}

impl SchedCfg {
    pub fn from_engine(cfg: &EngineCfg) -> SchedCfg {
        SchedCfg {
            method: cfg.method,
            block: cfg.block,
            refresh: cfg.refresh,
            sampler: cfg.sampler,
            seed: cfg.seed,
            k: cfg.fused_k,
            hysteresis: None,
        }
    }
}

/// One batch class's slot state: its slot array, token buffer, and
/// group caches. The scheduler owns one per configured class; only the
/// active class is ticked, the others hold parked state.
struct ClassState {
    batch: usize,
    slots: Vec<Option<SeqState>>,
    /// token layout per slot: [prompt (PAD-padded) | gen (MASK)]
    tokens: Vec<i32>,
    caches: GroupCaches,
}

impl ClassState {
    fn new(d: &Dims, batch: usize) -> ClassState {
        ClassState {
            batch,
            slots: (0..batch).map(|_| None).collect(),
            tokens: vec![0i32; batch * d.ctx],
            caches: GroupCaches::new(d, batch),
        }
    }

    fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    fn gen_row(&self, d: &Dims, slot: usize) -> &[i32] {
        &self.tokens[slot * d.ctx + d.prompt_len..(slot + 1) * d.ctx]
    }
}

/// A preempted sequence parked off its slot: the complete decode state
/// — [`SeqState`] (including the private sampling stream) plus the
/// token row. Parked only at a block boundary, so reseating the row
/// and letting the grounding prefill regenerate the device state is
/// trajectory-exact (see the module docs).
struct ParkedVictim {
    seq: SeqState,
    row: Vec<i32>,
    /// when this victim was parked — its aging clock (see
    /// [`GroupScheduler::set_park_promote`])
    parked_at: Instant,
}

impl ParkedVictim {
    /// Effective class under the aging ladder: base class promoted one
    /// level per `promote` interval of total parked time (this park plus
    /// any earlier ones banked in `park_credit`).
    fn effective_slo(&self, promote: Option<Duration>) -> SloClass {
        aged_class(
            self.seq.slo,
            self.seq.park_credit + self.parked_at.elapsed(),
            promote,
        )
    }
}

/// Outcome of a [`GroupScheduler::resume_victim`] attempt.
#[derive(Debug)]
pub enum ResumeOutcome {
    /// the victim was reseated into a free slot (its id)
    Seated(u64),
    /// the victim's deadline expired while parked: it retires here with
    /// a structured `timeout:` error instead of ever re-occupying a slot
    Shed(FinishedSeq),
    /// nothing parked, or no free slot
    None,
}

/// Fixed-slot group scheduler: the continuous-batching core, now over a
/// set of batch classes with pooled device residency (see the module
/// docs).
pub struct GroupScheduler<'a> {
    backend: Box<dyn StepBackend + 'a>,
    cfg: SchedCfg,
    /// configured batch classes, ascending (e.g. [1, 8])
    classes: Vec<usize>,
    /// index into `classes`/`states` of the class currently ticking
    active_class: usize,
    states: Vec<ClassState>,
    /// reusable sampling workspace shared by every slot's unmask decision
    scratch: SamplerScratch,
    /// group-level executable-run counters. With fusion (`cfg.k >= 2`)
    /// `n_es` counts DISPATCHES — a fused run is one `n_es` — while the
    /// per-sequence `SeqState::n_es` keeps counting iterations, so the
    /// two diverge by exactly the amortization won.
    pub ticks: usize,
    pub n_prefill: usize,
    pub n_dual: usize,
    pub n_es: usize,
    /// fused k-step dispatches issued (each covered ≥ 2 diffusion
    /// iterations in one device execution)
    pub n_fused: usize,
    /// EWMA over the demand samples seen by `maybe_switch_class`
    /// (meaningful only when `cfg.hysteresis` is set)
    demand_ewma: f64,
    /// demand evaluations left in the post-switch hold window
    hold_left: usize,
    /// sequences preempted off their slots at block boundaries, waiting
    /// for pressure to drop (highest-priority, then oldest, resumes
    /// first)
    parked_victims: Vec<ParkedVictim>,
    /// live-context tiering: when on, every tick sizes the dispatched
    /// context to the live decode frontier (see
    /// [`GroupScheduler::enable_live_ctx`]); off by default so the
    /// pre-tier ledger stays bit-identical
    live_ctx_enabled: bool,
    /// the tier currently applied to the backend (0 = not yet set)
    live_tier: usize,
    /// tier changes applied after the initial selection (each forces a
    /// full-group grounding prefill at the new shapes)
    pub tier_switches: usize,
    /// aging interval of the preemption starvation bound: a parked
    /// victim's effective class rises one priority level per interval
    /// of total parked time (see [`GroupScheduler::set_park_promote`])
    park_promote: Option<Duration>,
}

/// Default aging interval for parked preemption victims: long against a
/// tick, short against any client-visible deadline.
const DEFAULT_PARK_PROMOTE: Duration = Duration::from_millis(200);

impl<'a> GroupScheduler<'a> {
    /// Single-class scheduler over `n_slots` slots (the pre-pool
    /// behavior — no class switching).
    pub fn new(backend: Box<dyn StepBackend + 'a>, n_slots: usize, cfg: SchedCfg) -> Result<Self> {
        Self::with_classes(backend, &[n_slots.max(1)], cfg)
    }

    /// Scheduler over several batch classes. Starts on the largest class
    /// (full capacity); [`GroupScheduler::maybe_switch_class`] resizes
    /// from demand at block boundaries.
    pub fn with_classes(
        backend: Box<dyn StepBackend + 'a>,
        classes: &[usize],
        cfg: SchedCfg,
    ) -> Result<Self> {
        let d = *backend.dims();
        if cfg.block == 0 || d.gen_len % cfg.block != 0 {
            return Err(anyhow!(
                "gen_len {} not divisible by block {}",
                d.gen_len,
                cfg.block
            ));
        }
        let mut classes: Vec<usize> = classes.iter().map(|c| (*c).max(1)).collect();
        classes.sort_unstable();
        classes.dedup();
        if classes.is_empty() {
            classes.push(1);
        }
        let states = classes.iter().map(|&b| ClassState::new(&d, b)).collect();
        let active_class = classes.len() - 1;
        Ok(GroupScheduler {
            backend,
            cfg,
            classes,
            active_class,
            states,
            scratch: SamplerScratch::default(),
            ticks: 0,
            n_prefill: 0,
            n_dual: 0,
            n_es: 0,
            n_fused: 0,
            demand_ewma: 0.0,
            hold_left: 0,
            parked_victims: Vec::new(),
            live_ctx_enabled: false,
            live_tier: 0,
            tier_switches: 0,
            park_promote: Some(DEFAULT_PARK_PROMOTE),
        })
    }

    /// Set (or disable, with `None`) the aging interval of the
    /// preemption starvation bound. A parked victim's effective class
    /// rises one priority level per interval of total parked time, so a
    /// sustained burst of higher-class arrivals can delay it by at most
    /// `interval × (its class distance to latency_sensitive)` before it
    /// outranks fresh arrivals — which both resumes it ahead of them and
    /// (the credit survives reseating) shields it from being immediately
    /// re-preempted by the same burst.
    pub fn set_park_promote(&mut self, interval: Option<Duration>) {
        self.park_promote = interval;
    }

    /// Opt into live-context decoding: each tick the scheduler computes
    /// the group's live decode frontier — per occupied slot, `prompt +
    /// min(gen_len, (block_idx + 1) · block)` rows, maximized over the
    /// group — and dispatches at the smallest backend context tier that
    /// covers it ([`StepBackend::ctx_tiers`]). Fully-decoded suffix
    /// blocks beyond the frontier are pruned from the attention context
    /// (their committed tokens stay in the host mirror for the final
    /// downlink), and grounding prefills downlink only the current block
    /// window ([`StepBackend::run_prefill_blk`]). Every tier change —
    /// up when a sequence enters a block past the frontier, down when
    /// retirement shrinks it — forces a full-group grounding prefill,
    /// which regenerates every live row in-graph at the new shapes, so
    /// a pruned run is trajectory-exact with the full-context run (same
    /// unmask decisions from the same block-window logits). Off by
    /// default: with tiering off every dispatch and every ledger byte is
    /// identical to the pre-tier scheduler.
    pub fn enable_live_ctx(&mut self, on: bool) {
        self.live_ctx_enabled = on;
        if !on {
            let ctx = self.backend.dims().ctx;
            if self.live_tier != 0 && self.live_tier != ctx {
                self.backend.set_live_ctx(ctx);
            }
            self.live_tier = 0;
        }
    }

    /// Whether live-context tiering is on.
    pub fn live_ctx_enabled(&self) -> bool {
        self.live_ctx_enabled
    }

    /// The context tier currently applied to the backend (`None` before
    /// the first tiered tick or with tiering off).
    pub fn live_tier(&self) -> Option<usize> {
        (self.live_ctx_enabled && self.live_tier != 0).then_some(self.live_tier)
    }

    /// The backend's cumulative transfer ledger (resident-cache
    /// accounting; the router diffs this per tick into serving metrics).
    pub fn transfer_stats(&self) -> TransferStats {
        self.backend.transfer_stats()
    }

    /// The backend's cumulative residency-pool ledger (chain switches,
    /// avoided rebuilds, reseed bytes saved).
    pub fn pool_stats(&self) -> PoolStats {
        self.backend.pool_stats()
    }

    /// The backend's cumulative cross-request prefix-cache ledger
    /// (hits, misses, prefill bytes saved, cached bytes, evictions).
    pub fn prefix_stats(&self) -> PrefixStats {
        self.backend.prefix_stats()
    }

    /// Read access to the active class's group caches (dirty-bitmap
    /// inspection in tests and benches).
    pub fn group_caches(&self) -> &GroupCaches {
        &self.states[self.active_class].caches
    }

    /// Slot count of the active batch class.
    pub fn n_slots(&self) -> usize {
        self.states[self.active_class].batch
    }

    /// The active batch class (its slot count).
    pub fn batch_class(&self) -> usize {
        self.states[self.active_class].batch
    }

    /// The configured batch classes, ascending.
    pub fn classes(&self) -> &[usize] {
        &self.classes
    }

    pub fn active(&self) -> usize {
        self.states[self.active_class].active()
    }

    pub fn free_slots(&self) -> usize {
        self.n_slots() - self.active()
    }

    /// Ids of the currently resident sequences (for error draining).
    pub fn active_ids(&self) -> Vec<u64> {
        self.states[self.active_class].slots.iter().flatten().map(|s| s.id).collect()
    }

    /// Number of preempted sequences parked off their slots.
    pub fn parked(&self) -> usize {
        self.parked_victims.len()
    }

    /// Ids of the parked victims (error draining must cover them too —
    /// a parked sequence still has a client waiting on its reply).
    pub fn parked_ids(&self) -> Vec<u64> {
        self.parked_victims.iter().map(|v| v.seq.id).collect()
    }

    /// Effective service class of the best (highest-priority) parked
    /// victim, under the aging ladder: a long-parked victim reports a
    /// promoted class here, so the router's resume gate lets it beat
    /// fresh arrivals of the class it has aged into (the starvation
    /// bound — resume wins class ties against the queue).
    pub fn best_parked_class(&self) -> Option<SloClass> {
        let p = self.park_promote;
        self.parked_victims.iter().map(|v| v.effective_slo(p)).min()
    }

    /// Preempt one seated sequence on behalf of a waiter of class
    /// `waiter`: the victim must be of a strictly lower class and must
    /// sit at a block boundary (`i_b == 0` — the only trajectory-exact
    /// cut point; a mid-block victim is simply not eligible this tick).
    /// Among eligible victims the lowest class goes first, oldest last
    /// (LIFO within a class: the youngest did the least work). The
    /// victim's complete decode state parks beside the pooled chains
    /// and its slot is reset for the preemptor; resuming later replays
    /// nothing — the grounding prefill regenerates its device rows from
    /// the parked token mirror. Returns the victim's id, or `None` when
    /// no seated sequence is eligible.
    pub fn preempt_victim(&mut self, waiter: SloClass) -> Option<u64> {
        let ac = self.active_class;
        let d = *self.backend.dims();
        let promote = self.park_promote;
        let slot = {
            let st = &self.states[ac];
            (0..st.batch)
                .filter(|&s| {
                    st.slots[s].as_ref().is_some_and(|seq| {
                        // eligibility is judged at the AGED class: a
                        // reseated victim keeps its banked park credit,
                        // so the burst that parked it once cannot park
                        // it again (the starvation bound's other half)
                        aged_class(seq.slo, seq.park_credit, promote) > waiter && seq.i_b == 0
                    })
                })
                .max_by_key(|&s| {
                    let seq = st.slots[s].as_ref().unwrap();
                    (seq.slo, seq.admitted)
                })?
        };
        let st = &mut self.states[ac];
        let seq = st.slots[slot].take().unwrap();
        debug_assert_eq!(seq.i_b, 0, "preemption off a block boundary");
        let row = st.tokens[slot * d.ctx..(slot + 1) * d.ctx].to_vec();
        st.caches.reset_slot(slot);
        let id = seq.id;
        self.parked_victims.push(ParkedVictim { seq, row, parked_at: Instant::now() });
        self.backend.note_preempt(PreemptEvent::Parked);
        Some(id)
    }

    /// Reseat the best parked victim (highest class, then oldest) into
    /// a free slot of the active class. A victim whose deadline expired
    /// while parked is shed instead — returned as
    /// [`ResumeOutcome::Shed`] with the structured `timeout:` error a
    /// seated overdue sequence would get, so parked state never
    /// strands a client. The reseated sequence's next plan is its
    /// grounding prefill (`i_b == 0`), regenerating device state from
    /// the parked token mirror — trajectory-exact by the same argument
    /// as a batch-class switch.
    pub fn resume_victim(&mut self) -> ResumeOutcome {
        if self.parked_victims.is_empty() {
            return ResumeOutcome::None;
        }
        let promote = self.park_promote;
        let best = self
            .parked_victims
            .iter()
            .enumerate()
            .min_by_key(|(_, v)| (v.effective_slo(promote), v.seq.admitted))
            .map(|(i, _)| i)
            .unwrap();
        // shed an expired victim without consuming a slot
        let expired = {
            let seq = &self.parked_victims[best].seq;
            seq.timeout_ms
                .is_some_and(|ms| seq.submitted.elapsed().as_millis() as u64 >= ms)
        };
        let d = *self.backend.dims();
        if expired {
            let ParkedVictim { seq, row, .. } = self.parked_victims.remove(best);
            self.backend.note_preempt(PreemptEvent::Dropped);
            let gen_row = &row[d.prompt_len..];
            let mask = self.backend.tokenizer().mask;
            let tokens_out = gen_row[..seq.gen_len].iter().filter(|&&t| t != mask).count();
            let text = self.backend.tokenizer().decode(&gen_row[..seq.gen_len]);
            return ResumeOutcome::Shed(FinishedSeq {
                id: seq.id,
                text,
                iterations: seq.iters,
                tokens: tokens_out,
                n_prefill: seq.n_prefill,
                n_dual: seq.n_dual,
                n_es: seq.n_es,
                queue_s: seq.admitted.duration_since(seq.submitted).as_secs_f64(),
                gen_s: seq.admitted.elapsed().as_secs_f64(),
                error: Some(format!(
                    "timeout: exceeded {} ms after {} of {} positions (preempted)",
                    seq.timeout_ms.unwrap_or(0),
                    tokens_out,
                    seq.gen_len
                )),
                slo: seq.slo,
                ttft_s: seq.first_commit.map(|t| t.duration_since(seq.submitted).as_secs_f64()),
            });
        }
        let ac = self.active_class;
        let Some(slot) = self.states[ac].slots.iter().position(|s| s.is_none()) else {
            return ResumeOutcome::None;
        };
        let ParkedVictim { mut seq, row, parked_at } = self.parked_victims.remove(best);
        // bank this park's age: the credit keeps the victim's effective
        // class promoted after reseating, so the burst that parked it
        // cannot immediately re-preempt it
        seq.park_credit += parked_at.elapsed();
        let st = &mut self.states[ac];
        st.tokens[slot * d.ctx..(slot + 1) * d.ctx].copy_from_slice(&row);
        st.caches.reset_slot(slot);
        let id = seq.id;
        st.slots[slot] = Some(seq);
        self.backend.note_preempt(PreemptEvent::Resumed);
        ResumeOutcome::Seated(id)
    }

    /// True when every resident sequence sits at a block boundary
    /// (`i_b == 0`) — the only points where a batch-class switch is
    /// trajectory-exact, because every migrated sequence's next plan is
    /// the grounding prefill the refresh policy schedules at a block
    /// start anyway.
    pub fn at_block_boundary(&self) -> bool {
        self.states[self.active_class].slots.iter().flatten().all(|s| s.i_b == 0)
    }

    /// The batch class for `demand` concurrent sequences: the smallest
    /// configured class that fits them all, or the largest class when
    /// the demand exceeds every class.
    pub fn select_class(&self, demand: usize) -> usize {
        let demand = demand.max(1);
        self.classes
            .iter()
            .copied()
            .find(|&c| c >= demand)
            .unwrap_or(*self.classes.last().expect("at least one class"))
    }

    /// Resize the active batch class to the demand (`active + queued`
    /// sequences), if a switch is possible: multi-class scheduler, a
    /// different target class that fits the resident sequences, and
    /// every resident sequence at a block boundary. Returns whether a
    /// switch happened. The switch parks the outgoing class's retained
    /// chain in the residency pool and checks the incoming class's chain
    /// back out — no full KV reseed (see the module docs).
    ///
    /// Under [`SwitchHysteresis`] the downshift side is damped two
    /// ways: the demand is the max of the instantaneous sample and a
    /// rounded arrival-rate EWMA (a burst's memory keeps the class up
    /// through short lulls), and downshifts inside the post-switch hold
    /// window — counted in demand evaluations, i.e. calls to this
    /// method — are refused outright. Upshifts are never delayed.
    pub fn maybe_switch_class(&mut self, queued: usize) -> Result<bool> {
        if self.classes.len() < 2 {
            return Ok(false);
        }
        let active = self.active();
        let instantaneous = active + queued;
        let mut demand = instantaneous;
        let mut downshift_held = false;
        if let Some(h) = self.cfg.hysteresis {
            self.demand_ewma =
                h.alpha * instantaneous as f64 + (1.0 - h.alpha) * self.demand_ewma;
            demand = demand.max(self.demand_ewma.round() as usize);
            if self.hold_left > 0 {
                self.hold_left -= 1;
                downshift_held = true;
            }
        }
        let target = self.select_class(demand);
        if target == self.batch_class() || active > target || !self.at_block_boundary() {
            return Ok(false);
        }
        if downshift_held && target < self.batch_class() {
            return Ok(false);
        }
        self.switch_class(target)?;
        if let Some(h) = self.cfg.hysteresis {
            self.hold_left = h.hold;
        }
        Ok(true)
    }

    /// Switch to batch class `target`, migrating the resident sequences.
    /// Callers guarantee `target` is configured, fits the resident
    /// sequences, and that every resident sequence is at a block
    /// boundary (`i_b == 0`), so the migrated sequences' next plan — the
    /// grounding prefill — regenerates their rows in the new class
    /// exactly as it would have in the old one.
    fn switch_class(&mut self, target: usize) -> Result<()> {
        let from = self.active_class;
        let to = self
            .classes
            .iter()
            .position(|&c| c == target)
            .ok_or_else(|| anyhow!("no batch class {target}"))?;
        if to == from {
            return Ok(());
        }
        let d = *self.backend.dims();
        // refuse before touching anything: a failed switch must be
        // lossless (the resident sequences stay seated in `from`)
        let resident = self.states[from].active();
        if resident > target {
            return Err(anyhow!(
                "{resident} resident sequences cannot fit batch class {target}"
            ));
        }
        // park the outgoing chain, resume (or build) the incoming one —
        // all fallible work happens while the sequences are still seated
        // in `from`, so an error here loses nothing
        self.backend.park_chain(&mut self.states[from].caches);
        self.active_class = to;
        if let Err(e) = self.backend.checkout_chain(&mut self.states[to].caches) {
            // lossless unwind: fall back to the outgoing class (its
            // sequences never moved; worst case its chain re-activates
            // cold and the next prefill re-seeds)
            self.active_class = from;
            self.backend.checkout_chain(&mut self.states[from].caches)?;
            return Err(e);
        }
        self.backend.note_chain_switch();
        // lift the resident sequences (and their token rows — the whole
        // decode state) out of the outgoing class...
        let mut moved: Vec<(SeqState, Vec<i32>)> = Vec::new();
        {
            let st = &mut self.states[from];
            for s in 0..st.batch {
                if let Some(seq) = st.slots[s].take() {
                    debug_assert_eq!(seq.i_b, 0, "class switch off a block boundary");
                    moved.push((seq, st.tokens[s * d.ctx..(s + 1) * d.ctx].to_vec()));
                }
            }
        }
        // ...and re-seat them: the slot reset dirties their rows and the
        // next tick's grounding prefill regenerates them in the new
        // class (on device under ApplyMode::Device — no upload)
        let st = &mut self.states[to];
        for (seq, row) in moved {
            let slot = st
                .slots
                .iter()
                .position(|s| s.is_none())
                .expect("target class fits the resident sequences");
            st.tokens[slot * d.ctx..(slot + 1) * d.ctx].copy_from_slice(&row);
            st.caches.reset_slot(slot);
            st.slots[slot] = Some(seq);
        }
        Ok(())
    }

    /// Evict every resident sequence without producing results (used by
    /// the router to fail outstanding requests after a backend error).
    /// Also invalidates the backend's resident device caches for EVERY
    /// batch class — live and parked alike, including the pooled entries
    /// — because the sync planner's cleared dirty bits promise the
    /// device copy matches the host, and an eviction orphans that
    /// promise: a sequence admitted later must re-seed (or re-ground on
    /// device) rather than step against the evicted group's stale rows.
    pub fn evict_all(&mut self) {
        for _ in 0..self.parked_victims.len() {
            self.backend.note_preempt(PreemptEvent::Dropped);
        }
        self.parked_victims.clear();
        for st in self.states.iter_mut() {
            for s in st.slots.iter_mut() {
                *s = None;
            }
            self.backend.invalidate_resident(&mut st.caches);
        }
    }

    /// Admit a sequence into the lowest free slot of the active batch
    /// class. Fails with a `bad request:` message for invalid
    /// per-request parameters, or `no free slot` when the group is full
    /// (callers should check [`GroupScheduler::free_slots`] first).
    pub fn admit(&mut self, input: SeqInput) -> Result<usize> {
        let ac = self.active_class;
        let slot = self.states[ac]
            .slots
            .iter()
            .position(|s| s.is_none())
            .ok_or_else(|| anyhow!("no free slot"))?;
        let d = *self.backend.dims();
        let gen_len = input.params.gen_len.unwrap_or(d.gen_len);
        if gen_len == 0 || gen_len > d.gen_len || gen_len % self.cfg.block != 0 {
            return Err(anyhow!(
                "bad request: gen_len {gen_len} must be a positive multiple of \
                 block {} and at most {}",
                self.cfg.block,
                d.gen_len
            ));
        }
        let mut sampler = self.cfg.sampler;
        if let Some(t) = input.params.temperature {
            if !(0.0..=10.0).contains(&t) {
                return Err(anyhow!("bad request: temperature {t} out of range"));
            }
            sampler.temperature = t;
        }
        if let Some(th) = input.params.parallel_threshold {
            if !(0.0..=1.0).contains(&th) {
                return Err(anyhow!("bad request: threshold {th} out of range"));
            }
            sampler.parallel_threshold = Some(th);
        }
        if input.params.timeout_ms == Some(0) {
            return Err(anyhow!("bad request: timeout_ms must be positive"));
        }
        let tok = self.backend.tokenizer();
        let ids = tok
            .encode_prompt(&input.prompt, d.prompt_len)
            .map_err(|e| anyhow!("bad request: {e}"))?;
        let mask = tok.mask;
        let pad = tok.pad;
        let row = slot * d.ctx;
        self.states[ac].tokens[row..row + d.prompt_len].copy_from_slice(&ids);
        // the whole compiled gen region is masked regardless of the
        // requested gen_len (matches the training distribution); blocks
        // past gen_len are simply never scheduled
        for g in 0..d.gen_len {
            self.states[ac].tokens[row + d.prompt_len + g] = mask;
        }
        self.states[ac].caches.reset_slot(slot);
        // cross-request prefix reuse: probe the shared cache for the
        // longest block-aligned cached prefix of this prompt's content
        // tokens (padding stripped) and seed the slot's prompt-region KV
        // rows from the payload, so the grounding prefill only pays for
        // the unshared suffix. Prefix KV is a pure function of the
        // prompt tokens under the deterministic prefill, so a seeded
        // admission decodes exactly like a full-prefill one.
        let content_len = ids.iter().position(|&t| t == pad).unwrap_or(d.prompt_len);
        if let Some((p, rows)) = self.backend.prefix_probe(
            &ids[..content_len],
            self.cfg.block,
            &self.states[ac].caches,
        ) {
            self.states[ac].caches.merge_prefix_rows(slot, p, &rows)?;
        }
        // splitmix the request id into the seed so every request gets its
        // own deterministic sampling stream, independent of slot and of
        // the other occupants
        let seq_seed =
            self.cfg.seed ^ 0xE5D1 ^ (input.id.wrapping_add(1)).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        self.states[ac].slots[slot] = Some(SeqState {
            id: input.id,
            gen_len,
            sampler,
            rng: SplitMix::new(seq_seed),
            block_idx: 0,
            i_b: 0,
            iters: 0,
            n_prefill: 0,
            n_dual: 0,
            n_es: 0,
            submitted: input.submitted,
            admitted: Instant::now(),
            timeout_ms: input.params.timeout_ms,
            slo: input.params.slo,
            first_commit: None,
            park_credit: Duration::ZERO,
        });
        Ok(slot)
    }

    /// Re-ground the active class after a failed tick: invalidate its
    /// resident device state and run one grounding prefill over every
    /// occupied slot, regenerating chain + logits/conf mirrors from the
    /// host token mirror. The failed tick never mutated the trajectory
    /// (backend errors surface before the unmask phase), so the next
    /// [`GroupScheduler::tick`] re-plans and the recovered sequences
    /// produce token-identical output. Not counted as a decode
    /// iteration. Returns how many sequences were re-grounded.
    pub fn reground_active(&mut self) -> Result<usize> {
        let ac = self.active_class;
        let occupied: Vec<usize> = (0..self.states[ac].batch)
            .filter(|&s| self.states[ac].slots[s].is_some())
            .collect();
        let st = &mut self.states[ac];
        self.backend.invalidate_resident(&mut st.caches);
        if occupied.is_empty() {
            return Ok(0);
        }
        self.backend.run_prefill(&st.tokens, &occupied, &mut st.caches)?;
        Ok(occupied.len())
    }

    /// Step the fused dispatch depth down one rung (k → k/2, floored at
    /// 1 = unfused) after a poisoned-chain error. Returns the new depth,
    /// or `None` when already unfused.
    pub fn demote_fused_k(&mut self) -> Option<usize> {
        if self.cfg.k <= 1 {
            return None;
        }
        self.cfg.k = (self.cfg.k / 2).max(1);
        Some(self.cfg.k)
    }

    /// The current fused dispatch depth (1 = unfused).
    pub fn fused_k(&self) -> usize {
        self.cfg.k
    }

    /// The backend's fault injector, if it models faults.
    pub fn fault_injector(&self) -> Option<Arc<FaultInjector>> {
        self.backend.fault_injector()
    }

    /// Forward a recovery-ladder apply-mode override to the backend (see
    /// [`StepBackend::set_apply_override`]). Callers re-ground after.
    pub fn set_apply_override(&mut self, mode: Option<ApplyMode>) {
        self.backend.set_apply_override(mode);
    }

    /// Step every occupied slot of the active class one iteration;
    /// returns the sequences that retired at this tick's block
    /// boundaries.
    pub fn tick(&mut self) -> Result<Vec<FinishedSeq>> {
        let ac = self.active_class;
        let occupied: Vec<usize> = (0..self.states[ac].batch)
            .filter(|&s| self.states[ac].slots[s].is_some())
            .collect();
        if occupied.is_empty() {
            return Ok(Vec::new());
        }
        self.ticks += 1;

        // 0. live-context tier selection (opt-in). The live frontier is
        //    the furthest context row any occupied slot's CURRENT block
        //    reaches; the tier is the smallest compiled context that
        //    covers it. Both directions apply immediately — a sequence
        //    entering a block past the frontier must widen the context
        //    before its step, and a retirement shrinks it the very next
        //    tick. Every change forces a full-group grounding prefill:
        //    the retained chain's shapes change with the tier, and the
        //    prefill regenerates every live row in-graph at the new
        //    shapes (the same grounding a class switch relies on).
        let mut force_ground = false;
        if self.live_ctx_enabled {
            let d = *self.backend.dims();
            let frontier = occupied
                .iter()
                .map(|&s| {
                    let seq = self.states[ac].slots[s].as_ref().unwrap();
                    seq.gen_len.min((seq.block_idx + 1) * self.cfg.block)
                })
                .max()
                .unwrap_or(self.cfg.block);
            let need = d.prompt_len + frontier;
            let tier = self
                .backend
                .ctx_tiers()
                .into_iter()
                .filter(|&t| t >= need)
                .min()
                .unwrap_or(d.ctx);
            if self.live_tier == 0 && tier == d.ctx {
                // first selection already at the compiled maximum: the
                // backend starts there, so nothing changes shape
                self.live_tier = tier;
            } else if tier != self.live_tier {
                self.backend.set_live_ctx(tier);
                if self.live_tier != 0 {
                    self.tier_switches += 1;
                }
                self.live_tier = tier;
                force_ground = true;
            }
        }

        // 1. per-slot compute plan
        let mut prefill_slots: Vec<usize> = Vec::new();
        // key: (block index, plan discriminant) — BTreeMap for a
        // deterministic execution order
        let mut step_groups: BTreeMap<(usize, u8), Vec<usize>> = BTreeMap::new();
        for &s in &occupied {
            let seq = self.states[ac].slots[s].as_ref().unwrap();
            let plan = if force_ground {
                // tier-change tick: every occupant re-grounds at the new
                // context shapes before any step can chain
                StepPlan::Prefill
            } else {
                match self.cfg.method {
                    Method::Vanilla => StepPlan::Prefill,
                    Method::DualCache => RefreshPolicy::plan_dual(seq.i_b),
                    Method::EsDllm => self.cfg.refresh.plan_es(seq.iters, seq.i_b),
                }
            };
            match plan {
                StepPlan::Prefill => prefill_slots.push(s),
                StepPlan::DualStep => {
                    step_groups.entry((seq.block_idx, 0)).or_default().push(s)
                }
                StepPlan::EsStep => {
                    step_groups.entry((seq.block_idx, 1)).or_default().push(s)
                }
            }
        }

        // 2. one shared full forward for every slot that wants a prefill
        //    (block grounding, prompt refresh, vanilla step, admission)
        if !prefill_slots.is_empty() {
            {
                let st = &mut self.states[ac];
                if self.live_ctx_enabled {
                    // block-sliced downlink: each refreshed slot only
                    // needs its current block's logit rows re-merged —
                    // the unmask decision never reads outside the block.
                    // `starts` is batch-indexed (don't-care for slots
                    // outside the refresh set)
                    let mut starts = vec![0usize; st.batch];
                    for &s in &prefill_slots {
                        let seq = st.slots[s].as_ref().unwrap();
                        starts[s] = seq
                            .gen_len
                            .saturating_sub(self.cfg.block)
                            .min(seq.block_idx * self.cfg.block);
                    }
                    self.backend.run_prefill_blk(
                        &st.tokens,
                        &prefill_slots,
                        &starts,
                        self.cfg.block,
                        &mut st.caches,
                    )?;
                } else {
                    self.backend.run_prefill(&st.tokens, &prefill_slots, &mut st.caches)?;
                }
            }
            self.n_prefill += 1;
            for &s in &prefill_slots {
                self.states[ac].slots[s].as_mut().unwrap().n_prefill += 1;
            }
        }

        // 3. block steps, grouped by (block index, plan): sequences at
        //    different blocks each get a step at their own window.
        //    Groups of consecutive ES iterations may fuse into one
        //    k-step dispatch (see the module docs); `fused_commits`
        //    collects each fused slot's downlinked per-iteration
        //    commits so the unmask loop below applies them directly.
        let d = *self.backend.dims();
        let (mask, eos, pad) = {
            let tok = self.backend.tokenizer();
            (tok.mask, tok.eos, tok.pad)
        };
        let block = self.cfg.block;
        let mut fused_commits: Vec<Option<Vec<(usize, i32)>>> =
            vec![None; self.states[ac].batch];
        let groups: Vec<((usize, u8), Vec<usize>)> = step_groups.into_iter().collect();
        for ((blk, plan_tag), group) in groups {
            let plan = if plan_tag == 0 { StepPlan::DualStep } else { StepPlan::EsStep };
            let block_start = d.prompt_len + blk * block;
            // fusible depth of this group: min over members of the
            // per-slot bound — the refresh policy's consecutive-ES run
            // length and the block's remaining masked positions, under
            // greedy-only eligibility (each inner iteration commits
            // exactly one token, so a block can complete only at the
            // final inner iteration). The in-graph rule applies the EOS
            // guard unconditionally, so a guard-off sampler must take
            // the single-step path to keep its trajectory exact.
            let mut fuse = 1usize;
            if plan == StepPlan::EsStep && self.cfg.k >= 2 && self.cfg.method == Method::EsDllm {
                let st = &self.states[ac];
                fuse = self.cfg.k;
                for &s in &group {
                    let seq = st.slots[s].as_ref().unwrap();
                    if seq.sampler.temperature > 0.0
                        || seq.sampler.parallel_threshold.is_some()
                        || !seq.sampler.eos_guard
                    {
                        fuse = 1;
                        break;
                    }
                    let mut run = 0usize;
                    while run < fuse
                        && self.cfg.refresh.plan_es(seq.iters + run, seq.i_b + run)
                            == StepPlan::EsStep
                    {
                        run += 1;
                    }
                    let block_lo = seq.block_idx * block;
                    let masked = st.gen_row(&d, s)[block_lo..block_lo + block]
                        .iter()
                        .filter(|&&t| t == mask)
                        .count();
                    fuse = fuse.min(run).min(masked);
                    if fuse <= 1 {
                        break;
                    }
                }
            }
            let mut fused_n = 0usize;
            let mut commits = FusedCommits::new();
            if fuse >= 2 {
                let st = &mut self.states[ac];
                (fused_n, commits) = self.backend.run_step_fused(
                    &st.tokens,
                    block_start,
                    block,
                    fuse,
                    &group,
                    &mut st.caches,
                )?;
            }
            if fused_n >= 2 {
                // one dispatch advanced every member fused_n iterations;
                // stash each member's downlinked commit transcript for
                // the unmask loop
                if commits.len() != group.len() {
                    return Err(anyhow::Error::new(PoisonedChain(format!(
                        "fused run returned {} commit transcripts for {} slots",
                        commits.len(),
                        group.len()
                    ))));
                }
                for (&s, slot_commits) in group.iter().zip(commits) {
                    self.states[ac].slots[s].as_mut().unwrap().n_es += fused_n;
                    fused_commits[s] = Some(slot_commits);
                }
                self.n_es += 1;
                self.n_fused += 1;
                continue;
            }
            // single-step path (k = 1, ineligible slots, or the backend
            // declined the fused dispatch)
            {
                let st = &mut self.states[ac];
                self.backend
                    .run_step(plan, &st.tokens, block_start, block, &group, &mut st.caches)?;
            }
            for &s in &group {
                let seq = self.states[ac].slots[s].as_mut().unwrap();
                if plan == StepPlan::DualStep {
                    seq.n_dual += 1;
                } else {
                    seq.n_es += 1;
                }
            }
            if plan == StepPlan::DualStep {
                self.n_dual += 1;
            } else {
                self.n_es += 1;
            }
        }

        // 4. unmask decisions, per slot over its own current block. A
        //    slot a fused dispatch advanced applies the downlinked
        //    per-iteration commits VERBATIM — the device made those
        //    decisions with the host rule replicated in-graph, and
        //    re-deriving them from the final iteration's logits would
        //    desync the token mirror whenever an earlier commit changed
        //    the later ordering. Unfused slots decide host-side as
        //    always. Greedy fused slots never consume rng (temperature
        //    ≤ 0 returns before any draw), so skipping their host
        //    decisions preserves rng parity with k = 1.
        for &s in &occupied {
            if let Some(commits) = fused_commits[s].take() {
                let block_lo =
                    self.states[ac].slots[s].as_ref().unwrap().block_idx * block;
                for (p, t) in commits {
                    let cell = s * d.ctx + d.prompt_len + p;
                    let st = &mut self.states[ac];
                    if p < block_lo || p >= block_lo + block || st.tokens[cell] != mask
                    {
                        // the device committed outside the block window
                        // or onto an unmasked position: the in-graph
                        // transcript contradicts the mirror, so the
                        // chain built on it is unusable — fail loudly
                        // rather than continue desynced
                        self.backend.invalidate_resident(&mut st.caches);
                        return Err(anyhow::Error::new(PoisonedChain(format!(
                            "fused commit for slot {s} at gen position {p} \
                             (token {t}) falls outside block \
                             [{block_lo}, {}) or hits an unmasked cell",
                            block_lo + block
                        ))));
                    }
                    st.tokens[cell] = t;
                    let seq = st.slots[s].as_mut().unwrap();
                    seq.iters += 1;
                    seq.i_b += 1;
                    if seq.first_commit.is_none() {
                        seq.first_commit = Some(Instant::now());
                    }
                }
                continue;
            }
            {
                let decision = {
                    let st = &mut self.states[ac];
                    let block_lo = st.slots[s].as_ref().unwrap().block_idx * block;
                    let inp = UnmaskInput {
                        logits: &st.caches.logits
                            [s * d.gen_len * d.vocab..(s + 1) * d.gen_len * d.vocab],
                        conf: &st.caches.conf[s * d.gen_len..(s + 1) * d.gen_len],
                        gen_tokens: &st.tokens[s * d.ctx + d.prompt_len..(s + 1) * d.ctx],
                        block_lo,
                        block_hi: block_lo + block,
                        vocab: d.vocab,
                        mask_id: mask,
                        eos_id: eos,
                    };
                    let seq = st.slots[s].as_mut().unwrap();
                    decide_unmask_with(&seq.sampler, &inp, &mut seq.rng, &mut self.scratch)
                };
                let committed = !decision.positions.is_empty();
                for (p, t) in decision.positions.iter().zip(&decision.tokens) {
                    self.states[ac].tokens[s * d.ctx + d.prompt_len + p] = *t;
                }
                let seq = self.states[ac].slots[s].as_mut().unwrap();
                seq.iters += 1;
                seq.i_b += 1;
                if committed && seq.first_commit.is_none() {
                    seq.first_commit = Some(Instant::now());
                }
            }
        }

        // 5. block advance + retirement at block boundaries. A sequence
        //    whose per-request deadline has passed retires HERE — the
        //    block boundary is the only trajectory-safe cut point — with
        //    a structured `timeout:` error instead of its (partial)
        //    text, freeing the slot for the queue.
        let mut finished = Vec::new();
        for &s in &occupied {
            let (block_lo, gen_len) = {
                let seq = self.states[ac].slots[s].as_ref().unwrap();
                (seq.block_idx * self.cfg.block, seq.gen_len)
            };
            let block_done = {
                let row = self.states[ac].gen_row(&d, s);
                row[block_lo..block_lo + self.cfg.block].iter().all(|&t| t != mask)
            };
            if !block_done {
                continue;
            }
            let done = {
                let seq = self.states[ac].slots[s].as_mut().unwrap();
                seq.block_idx += 1;
                seq.i_b = 0;
                seq.block_idx * self.cfg.block >= seq.gen_len
            } || seq_complete(&self.states[ac].gen_row(&d, s)[..gen_len], mask, eos);
            // a completed sequence always delivers its result, deadline
            // or not (the work is already paid for); only an unfinished
            // overdue sequence is cut
            let timed_out = !done && {
                let seq = self.states[ac].slots[s].as_ref().unwrap();
                seq.timeout_ms
                    .is_some_and(|ms| seq.submitted.elapsed().as_millis() as u64 >= ms)
            };
            if done || timed_out {
                // live-context ledger: trailing blocks of this request's
                // gen budget that the EOS guard completed without ever
                // decoding (they were never scheduled, so they never
                // widened the live frontier)
                if done && self.live_ctx_enabled {
                    let decoded = self.states[ac].slots[s].as_ref().unwrap().block_idx;
                    let total = gen_len / self.cfg.block;
                    if decoded < total {
                        let st = &mut self.states[ac];
                        self.backend
                            .note_early_retire(&mut st.caches, (total - decoded) as u64);
                    }
                }
                let (text, tokens_out) = {
                    let row = &self.states[ac].gen_row(&d, s)[..gen_len];
                    let text = self.backend.tokenizer().decode(row);
                    let tokens_out = row.iter().filter(|&&t| t != mask).count();
                    (text, tokens_out)
                };
                // insert-on-retire: offer the retiring prompt's longest
                // block-aligned prefix to the shared cross-request
                // cache, so the next admission sharing it (multi-turn
                // chat, shared system prompts) seeds instead of
                // re-prefilling
                {
                    let prow = &self.states[ac].tokens
                        [s * d.ctx..s * d.ctx + d.prompt_len];
                    let clen =
                        prow.iter().position(|&t| t == pad).unwrap_or(d.prompt_len);
                    self.backend.prefix_offer(
                        &self.states[ac].tokens[s * d.ctx..s * d.ctx + clen],
                        self.cfg.block,
                        &self.states[ac].caches,
                        s,
                    );
                }
                let seq = self.states[ac].slots[s].take().unwrap();
                let error = timed_out.then(|| {
                    format!(
                        "timeout: exceeded {} ms after {} of {} positions",
                        seq.timeout_ms.unwrap_or(0),
                        tokens_out,
                        gen_len
                    )
                });
                finished.push(FinishedSeq {
                    id: seq.id,
                    text,
                    iterations: seq.iters,
                    tokens: tokens_out,
                    n_prefill: seq.n_prefill,
                    n_dual: seq.n_dual,
                    n_es: seq.n_es,
                    queue_s: seq.admitted.duration_since(seq.submitted).as_secs_f64(),
                    gen_s: seq.admitted.elapsed().as_secs_f64(),
                    error,
                    slo: seq.slo,
                    ttft_s: seq
                        .first_commit
                        .map(|t| t.duration_since(seq.submitted).as_secs_f64()),
                });
            }
        }
        Ok(finished)
    }
}

/// A sequence is complete when its first EOS has nothing masked before
/// it (the decoded text is fully determined — the EOS-guard early exit),
/// or when every position is unmasked.
pub fn seq_complete(gen_row: &[i32], mask: i32, eos: i32) -> bool {
    match gen_row.iter().position(|&t| t == eos) {
        Some(p) => gen_row[..p].iter().all(|&t| t != mask),
        None => gen_row.iter().all(|&t| t != mask),
    }
}

// ---------------------------------------------------------------------------
// PJRT backend: the real compiled artifacts behind a tick
// ---------------------------------------------------------------------------

/// [`StepBackend`] over the PJRT runtime and the compiled step
/// executables (the plumbing that used to live inside
/// `Engine::generate`).
///
/// Step I/O goes through a [`DeviceGroupCaches`] resident layer in one
/// of two modes, chosen at construction:
///
///   * [`ApplyMode::Device`] — when the artifacts carry the
///     `prefill_apply`/`step_apply` executables and the configuration is
///     eligible ([`crate::engine::device_apply_eligible`]). The
///     executables scatter their own KV/indicator updates into the
///     resident cache tensors in-graph and compute confidence in-graph;
///     the runtime retains those outputs
///     ([`crate::runtime::Runtime::run_retained`]) and this backend
///     chains them across ticks, so in steady state only block tokens
///     and the batch-bit occupancy mask go up and only the sampled
///     logit rows come down — the KV block never crosses the bus
///     mid-flight.
///   * [`ApplyMode::Host`] — the stateless-executable fallback (sparse
///     attention, indicator ablations, adaptive ratios, or artifact sets
///     without the apply variants): inputs are staged in pooled buffers
///     or borrowed straight from the group caches, uploads are retained
///     and reused while the dirty bitmaps allow, and step outputs are
///     downloaded and scattered host-side (their rows re-ship as
///     deltas).
///
/// Since the pooled-residency refactor the backend keeps one resident
/// layer **per batch class** (keyed by `caches.batch`, with the apply
/// mode and donation flag re-derived per class from the compiled
/// executables), parking and resuming chains through a shared
/// [`ResidencyPool`]. A PJRT worker parks under its own owner id: PJRT
/// buffers are not `Send`, so the handles never leave this thread and a
/// foreign worker's checkout deliberately misses.
pub struct PjrtBackend<'rt> {
    rt: &'rt Runtime,
    cfg: EngineCfg,
    arch: ArchSpec,
    /// primary batch class (what [`PjrtBackend::apply_mode`] reports)
    batch: usize,
    pool: Arc<ResidencyPool>,
    owner: Option<u64>,
    /// shared cross-request prefix cache (`None` = prefix reuse off:
    /// every admission pays the full grounding prefill). A PJRT worker
    /// probes and inserts under its own owner id — merged prefix rows
    /// re-sync through this worker's chain, so a foreign worker's
    /// entries would mis-credit the ledger (cross-worker PJRT prefix
    /// sharing is a follow-up for real bindings).
    prefix: Option<Arc<PrefixCache>>,
    /// resident layer per batch class, created on first activation and
    /// kept for the backend's lifetime (the ledger is cumulative)
    residents: BTreeMap<usize, DeviceGroupCaches>,
    /// classes whose chain is currently parked in the pool
    parked: BTreeSet<usize>,
    /// classes whose chain is live (activated and not parked/evicted)
    registered: BTreeSet<usize>,
    /// classes whose activation contributed to the pool's live-chain
    /// count (register_fresh or a per-owner checkout) — what park/evict
    /// must hand back so the gauge stays balanced
    counted: BTreeSet<usize>,
    last_flushed: TransferStats,
    /// deterministic fault injector built from
    /// [`EngineCfg::fault_plan`] (empty plan = never faults). Consulted
    /// at the same per-run event cadence as the sim backend's, so a
    /// fault ordinal fires at the same event on both backends and the
    /// [`crate::fault::FaultStats`] ledgers stay count-exact.
    injector: Arc<FaultInjector>,
    /// recovery-ladder quarantine: `Some(Host)` forces the stateless
    /// fallback for every class (a `Some(Device)` override is ignored —
    /// device-apply still requires the compiled executables)
    apply_override: Option<ApplyMode>,
    /// banked transfer ledger of resident layers retired by an
    /// apply-mode change (keeps `transfer_stats` monotone)
    retired_stats: TransferStats,
    /// scheduler-selected live-context tier (rows), applied to each
    /// class's resident planner at the next dispatch; the full context
    /// until [`StepBackend::set_live_ctx`] narrows it
    live_ctx_target: usize,
    /// mean |Δconfidence| at the last step — the adaptive-ratio signal.
    /// Group-scoped (shared by every occupant), matching the
    /// pre-refactor engine; see the module docs for the isolation
    /// caveat this implies under `cfg.adaptive`.
    pub conf_drift: f32,
}

impl<'rt> PjrtBackend<'rt> {
    /// Backend with a private residency pool (single-worker use: the
    /// engine façade, benches).
    pub fn new(rt: &'rt Runtime, cfg: EngineCfg, batch: usize) -> Result<PjrtBackend<'rt>> {
        Self::with_pool(rt, cfg, batch, ResidencyPool::new(), Some(0))
    }

    /// Backend sharing `pool` with other workers. `owner` must be unique
    /// per worker thread: parked PJRT chains are resumable only by the
    /// thread holding their device handles.
    pub fn with_pool(
        rt: &'rt Runtime,
        cfg: EngineCfg,
        batch: usize,
        pool: Arc<ResidencyPool>,
        owner: Option<u64>,
    ) -> Result<PjrtBackend<'rt>> {
        let arch = rt.arch(&cfg.arch)?.clone();
        let arch_ctx = arch.dims.ctx;
        let injector = FaultInjector::new(cfg.fault_plan.clone());
        Ok(PjrtBackend {
            rt,
            cfg,
            arch,
            batch,
            pool,
            owner,
            prefix: None,
            residents: BTreeMap::new(),
            parked: BTreeSet::new(),
            registered: BTreeSet::new(),
            counted: BTreeSet::new(),
            last_flushed: TransferStats::default(),
            injector,
            apply_override: None,
            retired_stats: TransferStats::default(),
            live_ctx_target: arch_ctx,
            conf_drift: 1.0,
        })
    }

    /// Wire the shared cross-request prefix cache (the router does this
    /// for every worker before serving). Prefix reuse is off until set.
    pub fn set_prefix_cache(&mut self, cache: Arc<PrefixCache>) {
        self.prefix = Some(cache);
    }

    /// Apply mode for one batch class: device-apply needs every
    /// executable the config can reach at that class, or a
    /// mid-generation plan would have to fall back with a cold chain.
    fn apply_for(&self, batch: usize) -> ApplyMode {
        // a Host quarantine overrides eligibility wholesale; a Device
        // override is meaningless (the compiled executables still gate)
        if self.apply_override == Some(ApplyMode::Host) {
            return ApplyMode::Host;
        }
        if device_apply_eligible(&self.cfg)
            && self.arch.executables.contains_key(&prefill_apply_exe_name(batch))
            && self
                .arch
                .executables
                .contains_key(&apply_step_exe_name(StepPlan::DualStep, self.cfg.block, batch))
            && (self.cfg.method != Method::EsDllm
                || self
                    .arch
                    .executables
                    .contains_key(&apply_step_exe_name(StepPlan::EsStep, self.cfg.block, batch)))
        {
            ApplyMode::Device
        } else {
            ApplyMode::Host
        }
    }

    /// Whether every apply executable this config chains at `batch` was
    /// compiled with the input-output alias config (manifest `alias`
    /// signatures) — the ledger may report an execution as donated only
    /// then; an older alias-less artifact set still chains correctly, by
    /// replace-and-drop.
    fn donation_for(&self, batch: usize) -> bool {
        let n_params = self.arch.params.len();
        let donated = |name: &str| {
            self.arch
                .executables
                .get(name)
                .map(|e| !e.alias_pairs(n_params).is_empty())
                .unwrap_or(false)
        };
        donated(&prefill_apply_exe_name(batch))
            && donated(&apply_step_exe_name(StepPlan::DualStep, self.cfg.block, batch))
            && (self.cfg.method != Method::EsDllm
                || donated(&apply_step_exe_name(StepPlan::EsStep, self.cfg.block, batch)))
    }

    /// The live-context tier dispatches actually run at for `batch`: the
    /// scheduler's target, floored back to the full context unless the
    /// artifacts carry the COMPLETE tier family this config can reach at
    /// that class — a mid-generation plan must never discover its tier
    /// executable missing with the chain already shaped for the tier.
    fn effective_live(&self, batch: usize) -> usize {
        let ctx = self.arch.dims.ctx;
        let live = self.live_ctx_target;
        if live == 0 || live >= ctx {
            return ctx;
        }
        let has = |base: &str| {
            self.arch.executables.contains_key(&self.arch.tier_exe_name(base, live))
        };
        if has(&prefill_apply_exe_name(batch))
            && has(&apply_step_exe_name(StepPlan::DualStep, self.cfg.block, batch))
            && (self.cfg.method != Method::EsDllm
                || has(&apply_step_exe_name(StepPlan::EsStep, self.cfg.block, batch)))
        {
            live
        } else {
            ctx
        }
    }

    /// Apply the scheduler's live-context target to this class's
    /// resident planner before a dispatch. A tier change drops the
    /// retained chain handles — their device shapes belong to the old
    /// tier — and the grounding prefill the scheduler forces on the
    /// same tick re-seeds them at the new shapes and regenerates every
    /// live row in-graph. The planner's seeded state carries over (the
    /// reshape is modeled as an in-place device realloc, not a host
    /// reseed), so no reseed bytes are charged — matching the sim
    /// planner byte-for-byte.
    fn apply_live_ctx(&mut self, batch: usize) {
        let live = self.effective_live(batch);
        let r = self.residents.get_mut(&batch).expect("activated");
        if r.apply_mode() == ApplyMode::Device && r.live_ctx() != live {
            r.chain.handles.kv_chain = None;
            r.chain.handles.ind_chain = None;
            r.chain.handles.conf_chain = None;
            r.set_live_ctx(live);
        }
    }

    /// Zero chain-seed tensors (kv, ind, conf) at a narrowed context
    /// tier of `live` rows. Contents are irrelevant: the tier seed only
    /// exists so the first tiered execution has chain inputs of the
    /// right shape, and the full-group grounding prefill the scheduler
    /// forces on the tier-change tick regenerates every occupied row
    /// in-graph (vacant rows are garbage by the spectator contract).
    fn tier_seed_zeros(d: &Dims, batch: usize, live: usize) -> (HostTensor, HostTensor, HostTensor) {
        let g = live - d.prompt_len;
        (
            HostTensor::zeros(
                DType::Bf16,
                &[d.n_layers, 2, batch, d.n_kv_heads, live, d.head_dim],
            ),
            HostTensor::zeros(DType::Bf16, &[d.n_layers, batch, g, d.d_model]),
            HostTensor::zeros(DType::F32, &[batch, g]),
        )
    }

    /// The prefill token uplink view at the current tier: the pooled
    /// `[B, ctx]` staging buffer as-is at the full context, or a
    /// `[B, live]` row-sliced copy at a narrower tier (the tiered
    /// executables take `prompt + gen_live` token columns).
    fn tier_tokens(&self, batch: usize, live: usize) -> Result<Option<HostTensor>> {
        if live >= self.arch.dims.ctx {
            return Ok(None);
        }
        let r = &self.residents[&batch];
        let full = r.prefill_tokens.as_i32()?;
        let ctx = self.arch.dims.ctx;
        let mut data = Vec::with_capacity(batch * live);
        for b in 0..batch {
            data.extend_from_slice(&full[b * ctx..b * ctx + live]);
        }
        Ok(Some(HostTensor::I32 { shape: vec![batch, live], data }))
    }

    /// Seed any cold retained chain handles (first call of a chain,
    /// post-invalidation, or a tier change dropped them): the host cache
    /// views at the full context — the one whole-cache upload of a
    /// generation — or zero tensors of the tier shapes at a narrower
    /// tier ([`PjrtBackend::tier_seed_zeros`]).
    fn seed_chain(&mut self, batch: usize, live: usize, caches: &GroupCaches) -> Result<()> {
        let d = self.arch.dims;
        let tier = (live < d.ctx).then(|| Self::tier_seed_zeros(&d, batch, live));
        let r = self.residents.get_mut(&batch).expect("activated");
        if r.chain.handles.kv_chain.is_none() {
            let (buf, lit) = match &tier {
                Some((kv, _, _)) => self.rt.upload_tensor_view(&kv.view())?,
                None => self.rt.upload_tensor_view(&caches.kv_view())?,
            };
            r.chain.handles.kv_chain = Some(UploadHandle { buf, lit });
        }
        if r.chain.handles.ind_chain.is_none() {
            let (buf, lit) = match &tier {
                Some((_, ind, _)) => self.rt.upload_tensor_view(&ind.view())?,
                None => self.rt.upload_tensor_view(&caches.ind_view("h")?)?,
            };
            r.chain.handles.ind_chain = Some(UploadHandle { buf, lit });
        }
        if r.chain.handles.conf_chain.is_none() {
            let (buf, lit) = match &tier {
                Some((_, _, conf)) => self.rt.upload_tensor_view(&conf.view())?,
                None => self.rt.upload_tensor_view(&caches.conf_view())?,
            };
            r.chain.handles.conf_chain = Some(UploadHandle { buf, lit });
        }
        Ok(())
    }

    /// Activate the resident layer for `caches`' batch class: resume the
    /// parked chain, check a pooled plan out, or build a fresh layer.
    /// Idempotent for an already-live class.
    ///
    /// Chain seed/checkout is an allocation event: an injected
    /// allocation fault first evicts the pool's LRU parked entry (the
    /// free-device-memory ladder rung) and only surfaces as an error
    /// when the pool has nothing left to evict.
    fn activate(&mut self, caches: &mut GroupCaches) -> Result<()> {
        let batch = caches.batch;
        if self.registered.contains(&batch) && !self.parked.contains(&batch) {
            return Ok(()); // live and counted — nothing to do
        }
        if let Err(f) = self.injector.check(FaultKind::Alloc) {
            if self.pool.evict_lru(1).is_empty() {
                return Err(anyhow::Error::from(f)
                    .context(format!("chain seed/checkout for class {batch}")));
            }
            // absorbed: an LRU parked chain was evicted to make room
        }
        let seed = chain_seed_bytes(&self.arch.dims, batch);
        if self.parked.remove(&batch) {
            // our own parked chain: the plan comes back out of the pool
            // and lines up with the handles this thread kept
            match self.pool.checkout(&self.cfg.arch, batch, self.owner, seed) {
                Some(plan) => {
                    self.residents
                        .get_mut(&batch)
                        .expect("parked implies a resident entry")
                        .restore_plan(plan);
                    // a per-owner checkout moved the chain back to the
                    // live count (a shared clone would not have)
                    if self.owner.is_some() {
                        self.counted.insert(batch);
                    }
                }
                None => {
                    // the pooled entry was evicted while parked: the
                    // promise is gone, re-seed from scratch
                    if let Some(r) = self.residents.get_mut(&batch) {
                        r.invalidate(caches);
                    }
                    self.pool.register_fresh();
                    self.counted.insert(batch);
                }
            }
            self.registered.insert(batch);
            return Ok(());
        }
        if self.residents.contains_key(&batch) {
            // evicted earlier and now reactivated: it re-seeds from
            // scratch, as a fresh chain
            self.pool.register_fresh();
            self.counted.insert(batch);
        } else {
            let apply = self.apply_for(batch);
            // a pool checkout here can only miss for a PJRT worker (the
            // owner key is unique per thread and parking keeps the
            // resident entry alive), but the call keeps this activation
            // path identical to the sim backend's — the parity the
            // transfer-accounting tests pin
            let mut r = match self.pool.checkout(&self.cfg.arch, batch, self.owner, seed) {
                Some(plan) => {
                    if self.owner.is_some() {
                        self.counted.insert(batch);
                    }
                    DeviceGroupCaches::with_plan(&self.arch.dims, batch, apply, plan)
                }
                None => {
                    self.pool.register_fresh();
                    self.counted.insert(batch);
                    DeviceGroupCaches::new(&self.arch.dims, batch, apply)
                }
            };
            if apply == ApplyMode::Device {
                r.set_donation(self.donation_for(batch));
            }
            self.residents.insert(batch, r);
        }
        self.registered.insert(batch);
        Ok(())
    }

    /// Filter candidate batch classes to those the compiled artifacts
    /// can serve for this configuration — e.g. the block-32 step
    /// executables exist only at b = 8, and the ablation/adaptive
    /// variants are single-class — so the router never offers a class
    /// that would fail at its first step. Falls back to the primary
    /// class when nothing else qualifies.
    pub fn supported_classes(&self, classes: &[usize]) -> Vec<usize> {
        let ok = |batch: usize| -> bool {
            // variant-override and adaptive configs pick executables
            // dynamically and are compiled for one class only
            if self.cfg.adaptive || self.cfg.es_exe_override.is_some() {
                return batch == self.batch;
            }
            if self.cfg.method == Method::Vanilla {
                return self.arch.executables.contains_key(&format!("vanilla_b{batch}"));
            }
            if !self.arch.executables.contains_key(&format!("prefill_b{batch}")) {
                return false;
            }
            let dual = step_exe_name(&self.cfg, StepPlan::DualStep, batch, 1.0);
            if !self.arch.executables.contains_key(&dual) {
                return false;
            }
            if self.cfg.method == Method::EsDllm {
                let es = step_exe_name(&self.cfg, StepPlan::EsStep, batch, 1.0);
                if !self.arch.executables.contains_key(&es) {
                    return false;
                }
            }
            true
        };
        let mut v: Vec<usize> = classes.iter().copied().filter(|&c| ok(c)).collect();
        if v.is_empty() {
            v.push(self.batch);
        }
        v
    }

    /// Which apply mode this backend selects for its primary batch class
    /// (visible for tests and the perf benches).
    pub fn apply_mode(&self) -> ApplyMode {
        self.residents
            .get(&self.batch)
            .map(|r| r.apply_mode())
            .unwrap_or_else(|| self.apply_for(self.batch))
    }

    /// Cumulative ledger merged across every batch class's resident
    /// layer (monotone, so per-tick `since` deltas stay valid).
    fn merged_stats(&self) -> TransferStats {
        let mut total = self.retired_stats;
        for r in self.residents.values() {
            total.merge(&r.stats);
        }
        total
    }

    /// Consult the injector for the modeled run + downlink fault events
    /// of one dispatch (same cadence as the sim backend); on a fault,
    /// invalidate this class's resident state — the real run never
    /// delivered — and return the typed error for the recovery loop.
    fn check_run_faults(&mut self, caches: &mut GroupCaches, what: &str) -> Result<()> {
        if let Err(f) = self.injector.check(FaultKind::Exec) {
            self.invalidate_resident(caches);
            return Err(anyhow::Error::from(f).context(format!("{what} run")));
        }
        if let Err(f) = self.injector.check(FaultKind::Transfer) {
            self.invalidate_resident(caches);
            return Err(anyhow::Error::from(f).context(format!("{what} downlink")));
        }
        Ok(())
    }

    /// Mirror the planner-ledger growth into the runtime's stats so
    /// `Runtime::take_stats` reports the logical transfer picture.
    fn flush_transfer(&mut self) {
        let now = self.merged_stats();
        let delta = now.since(&self.last_flushed);
        self.rt.note_transfer(&delta);
        self.last_flushed = now;
    }

    /// Adaptive-ratio signal: mean |Δconfidence| over the given slots'
    /// gen positions [lo, hi). Note the drift is per backend, i.e. per
    /// group: a fresh `Engine::generate` starts back at the conservative
    /// default rather than inheriting the previous group's drift.
    fn update_drift(
        &mut self,
        caches: &GroupCaches,
        before: &[f32],
        slots: &[usize],
        lo: usize,
        hi: usize,
    ) {
        let gen = self.arch.dims.gen_len;
        let mut sum = 0f32;
        let mut cnt = 0usize;
        for &b in slots {
            for j in lo..hi {
                let i = b * gen + j;
                sum += (caches.conf[i] - before[i]).abs();
                cnt += 1;
            }
        }
        self.conf_drift = sum / cnt.max(1) as f32;
    }
}

impl Drop for PjrtBackend<'_> {
    fn drop(&mut self) {
        // this worker's device buffers die with it: return the live
        // count and drop the per-owner parked entries no thread can ever
        // resume, so a worker that exits or panics mid-serve can never
        // permanently inflate the shared `resident_chains` gauge
        for &batch in &self.parked {
            self.pool.evict(&self.cfg.arch, batch, self.owner, false);
        }
        self.pool.release(self.counted.len() as u64);
    }
}

impl StepBackend for PjrtBackend<'_> {
    fn dims(&self) -> &Dims {
        &self.arch.dims
    }

    fn tokenizer(&self) -> &Tokenizer {
        &self.rt.tokenizer
    }

    fn run_prefill(
        &mut self,
        tokens: &[i32],
        slots: &[usize],
        caches: &mut GroupCaches,
    ) -> Result<()> {
        self.activate(caches)?;
        self.apply_live_ctx(caches.batch);
        self.check_run_faults(caches, "prefill")?;
        let batch = caches.batch;
        if self.residents[&batch].apply_mode() == ApplyMode::Device {
            let result = self.prefill_device_impl(tokens, slots, caches);
            if result.is_err() {
                // the sync planner seeded/reused the chain for a run that
                // never delivered; take the promise back wholesale
                if let Some(r) = self.residents.get_mut(&batch) {
                    r.invalidate(caches);
                }
            }
            return result;
        }
        let d = self.arch.dims;
        // row-filtered staging: only the refreshed slots' rows are copied
        // into the persistent upload buffer (no whole-group tokens clone)
        self.residents
            .get_mut(&batch)
            .expect("activated")
            .stage_prefill_tokens(tokens, slots);
        // the vanilla baseline never reads caches: logits-only executable
        if self.cfg.method == Method::Vanilla {
            let exe = self.arch.exe(&format!("vanilla_b{batch}"))?;
            // the compile pipeline slices the fallback logits to the gen
            // region too (`logits_gen`); older artifact sets still ship
            // the full context
            let gen_sliced = exe.output_index("logits_gen").is_ok();
            let args = [ExecArg::Host(self.residents[&batch].prefill_tokens.view())];
            let out = self.rt.run_args(&self.arch, exe, &self.cfg.checkpoint, &args)?;
            self.flush_transfer();
            return if gen_sliced {
                caches.merge_gen_logits_slots(&out[0], slots)
            } else {
                caches.merge_full_logits_slots(&out[0], slots)
            };
        }
        let conf_before = self.cfg.adaptive.then(|| caches.conf.clone());
        let exe = self.arch.exe(&format!("prefill_b{batch}"))?;
        let args = [ExecArg::Host(self.residents[&batch].prefill_tokens.view())];
        let out = self.rt.run_args(&self.arch, exe, &self.cfg.checkpoint, &args)?;
        debug_assert_eq!(exe.kind, ExeKind::Prefill);
        caches.refresh_slots_from_prefill(&out, slots)?;
        if self.cfg.sparse {
            let keep = self.rt.manifest.generation.sparse_keep_prompt;
            caches.rebuild_sparse_slots(&out[6], keep, 3, slots)?;
        }
        // under a device-apply transport the prefill outputs would refresh
        // the resident rows in place (no-op in Host mode)
        self.residents
            .get_mut(&batch)
            .expect("activated")
            .note_prefill_applied(caches, slots);
        self.flush_transfer();
        // prompt refreshes move confidence the most, so they must feed the
        // adaptive-ratio signal too (the pre-refactor engine measured the
        // drift on every plan); without the per-slot block window here, the
        // whole gen region of the refreshed slots approximates it
        let gen_len = d.gen_len;
        if let Some(before) = conf_before {
            self.update_drift(caches, &before, slots, 0, gen_len);
        }
        Ok(())
    }

    fn run_step(
        &mut self,
        plan: StepPlan,
        tokens: &[i32],
        block_start: usize,
        block: usize,
        slots: &[usize],
        caches: &mut GroupCaches,
    ) -> Result<()> {
        self.activate(caches)?;
        self.apply_live_ctx(caches.batch);
        self.check_run_faults(caches, "step")?;
        let batch = caches.batch;
        let result = if self.residents[&batch].apply_mode() == ApplyMode::Device {
            self.step_device_impl(plan, tokens, block_start, block, slots, caches)
        } else {
            self.step_impl(plan, tokens, block_start, block, slots, caches)
        };
        if result.is_err() {
            // the sync planner cleared dirty bits (or chained retained
            // outputs) for a run that never completed; forget the
            // resident state so a later tick on this scheduler cannot
            // execute against a stale device copy
            if let Some(r) = self.residents.get_mut(&batch) {
                r.invalidate(caches);
            }
        }
        result
    }

    fn run_step_fused(
        &mut self,
        tokens: &[i32],
        block_start: usize,
        block: usize,
        k: usize,
        slots: &[usize],
        caches: &mut GroupCaches,
    ) -> Result<(usize, FusedCommits)> {
        self.activate(caches)?;
        self.apply_live_ctx(caches.batch);
        let batch = caches.batch;
        if self.residents[&batch].apply_mode() != ApplyMode::Device {
            return Ok((0, FusedCommits::new())); // fused variants exist only on the apply path
        }
        // floor the requested depth to the deepest compiled unroll that
        // fits the run — at the CURRENT context tier (a fused depth
        // compiled only at the full context cannot serve a narrowed
        // chain); decline entirely when none was compiled
        let live = self.residents[&batch].live_ctx();
        let Some(depth) = FUSED_KS.iter().copied().find(|&kk| {
            kk <= k
                && self
                    .arch
                    .executables
                    .get(&self.arch.tier_exe_name(
                        &fused_step_exe_name(kk, self.cfg.block, batch),
                        live,
                    ))
                    .map(|e| e.kind == ExeKind::StepApplyK)
                    .unwrap_or(false)
        }) else {
            return Ok((0, FusedCommits::new()));
        };
        // modeled fault events of an accepted fused dispatch: run,
        // downlink, and the committed-count audit (diverge)
        self.check_run_faults(caches, "fused step")?;
        if let Err(f) = self.injector.check(FaultKind::FusedDivergence) {
            self.invalidate_resident(caches);
            return Err(
                anyhow::Error::from(f).context("fused committed-count audit")
            );
        }
        let result = self.step_device_k_impl(depth, tokens, block_start, block, slots, caches);
        if result.is_err() {
            // same contract as run_step: a planner sync that promised a
            // run which never delivered — or a failed commit audit —
            // invalidates the resident state (rollback is impossible:
            // donation already consumed the previous chain buffers)
            if let Some(r) = self.residents.get_mut(&batch) {
                r.invalidate(caches);
            }
        }
        result.map(|commits| (depth, commits))
    }

    fn transfer_stats(&self) -> TransferStats {
        self.merged_stats()
    }

    fn ctx_tiers(&self) -> Vec<usize> {
        self.rt.manifest.generation.ctx_tiers.clone()
    }

    fn set_live_ctx(&mut self, rows: usize) {
        self.live_ctx_target = rows;
    }

    fn note_early_retire(&mut self, caches: &mut GroupCaches, blocks: u64) {
        if let Some(r) = self.residents.get_mut(&caches.batch) {
            r.note_early_retired(blocks);
        }
    }

    fn run_prefill_blk(
        &mut self,
        tokens: &[i32],
        slots: &[usize],
        block_starts: &[usize],
        block: usize,
        caches: &mut GroupCaches,
    ) -> Result<()> {
        self.activate(caches)?;
        let batch = caches.batch;
        self.apply_live_ctx(batch);
        // the sliced downlink needs the blk executable (at the current
        // tier) and the device-apply transport; otherwise the full
        // gen-region prefill serves the same request
        let blk_ok = self.residents[&batch].apply_mode() == ApplyMode::Device && {
            let live = self.residents[&batch].live_ctx();
            self.arch
                .executables
                .contains_key(&self.arch.tier_exe_name(&prefill_apply_blk_exe_name(block, batch), live))
        };
        if !blk_ok {
            return self.run_prefill(tokens, slots, caches);
        }
        self.check_run_faults(caches, "prefill")?;
        let result = self.prefill_device_blk_impl(tokens, slots, block_starts, block, caches);
        if result.is_err() {
            if let Some(r) = self.residents.get_mut(&batch) {
                r.invalidate(caches);
            }
        }
        result
    }

    fn invalidate_resident(&mut self, caches: &mut GroupCaches) {
        let batch = caches.batch;
        if let Some(r) = self.residents.get_mut(&batch) {
            r.invalidate(caches);
            // the pooled entry (parked or live) dies with the chain: a
            // later checkout must re-seed, never resume evicted state
            self.registered.remove(&batch);
            self.parked.remove(&batch);
            let was_active = self.counted.remove(&batch);
            self.pool.evict(&self.cfg.arch, batch, self.owner, was_active);
        }
    }

    fn park_chain(&mut self, caches: &mut GroupCaches) {
        let batch = caches.batch;
        if let Some(r) = self.residents.get(&batch) {
            if self.registered.remove(&batch) && self.parked.insert(batch) {
                let was_active = self.counted.remove(&batch);
                self.pool
                    .park(&self.cfg.arch, batch, self.owner, r.park_plan(), was_active);
            }
        }
    }

    fn checkout_chain(&mut self, caches: &mut GroupCaches) -> Result<()> {
        self.activate(caches)
    }

    fn note_chain_switch(&self) {
        self.pool.record_switch();
    }

    fn note_preempt(&self, ev: PreemptEvent) {
        self.pool.note_victim(ev);
    }

    fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }

    fn prefix_probe(
        &mut self,
        content: &[i32],
        block: usize,
        caches: &GroupCaches,
    ) -> Option<(usize, Vec<u16>)> {
        // probe under this worker's owner id: the merged rows re-sync
        // through this worker's chain (same split as the pool)
        let cache = self.prefix.as_ref()?;
        cache.probe(&self.cfg.arch, self.owner, content, block, caches.kv_row_bytes() as u64)
    }

    fn prefix_offer(
        &mut self,
        content: &[i32],
        block: usize,
        caches: &GroupCaches,
        slot: usize,
    ) {
        let Some(cache) = self.prefix.as_ref() else {
            return;
        };
        if block == 0 {
            return;
        }
        let p = (content.len() / block) * block;
        if p == 0 {
            return;
        }
        let Ok(rows) = caches.extract_prefix_rows(slot, p) else {
            return;
        };
        cache.insert(&self.cfg.arch, self.owner, &content[..p], rows);
    }

    fn prefix_stats(&self) -> PrefixStats {
        self.prefix.as_ref().map(|c| c.stats()).unwrap_or_default()
    }

    fn fault_injector(&self) -> Option<Arc<FaultInjector>> {
        Some(self.injector.clone())
    }

    fn set_apply_override(&mut self, mode: Option<ApplyMode>) {
        if self.apply_override == mode {
            return;
        }
        self.apply_override = mode;
        // resident layers are built for one apply mode, so a quarantine
        // (or a re-probe back) retires them all: ledgers bank so
        // `transfer_stats` stays monotone, pooled entries are evicted
        // (their device handles die with the layers), and the next
        // activation re-derives each class's mode — the caller
        // re-grounds afterwards
        for (&batch, r) in self.residents.iter() {
            self.retired_stats.merge(&r.stats);
            let was_active = self.counted.contains(&batch);
            self.pool.evict(&self.cfg.arch, batch, self.owner, was_active);
        }
        self.residents.clear();
        self.registered.clear();
        self.parked.clear();
        self.counted.clear();
    }
}

impl PjrtBackend<'_> {
    fn step_impl(
        &mut self,
        plan: StepPlan,
        tokens: &[i32],
        block_start: usize,
        block: usize,
        slots: &[usize],
        caches: &mut GroupCaches,
    ) -> Result<()> {
        let d = self.arch.dims;
        let batch = caches.batch;
        let exe_name = step_exe_name(&self.cfg, plan, batch, self.conf_drift);
        let exe = self.arch.exe(&exe_name)?;
        let r = self.residents.get_mut(&batch).expect("activated");

        // current block tokens for the stepped rows, staged in the pooled
        // buffer (spectator rows keep stale contents; their outputs are
        // discarded by the row-filtered merges below)
        r.stage_step_tokens(tokens, block_start, block, slots);

        let ind_layers: &[usize] = &exe.skip_layers;
        let all_layers: Vec<usize> = (0..d.n_layers).collect();
        let ind_for_exe: Vec<usize> = if exe.skip.is_empty() {
            all_layers
        } else {
            ind_layers.to_vec()
        };
        let indicator = exe.indicator.clone().unwrap_or_else(|| "h".into());

        // dirty-delta syncs: each returns how many bytes a delta-capable
        // transport ships; shipped == 0 means the retained device buffer
        // is still valid for the reading slots and is reused outright
        let kv_sync: SyncOutcome = if self.cfg.sparse {
            r.sync_kv_sparse(caches, slots)?
        } else {
            r.sync_kv(caches, slots)
        };
        let ind_sync = r.sync_ind(caches, &indicator, &ind_for_exe, slots)?;
        let conf_sync = r.sync_conf_masked(caches, slots);

        let conf_before = self.cfg.adaptive.then(|| caches.conf.clone());

        // refresh retained handles for anything that shipped (the PJRT
        // client has no partial-buffer write, so a dirty input re-uploads
        // whole — the delta numbers stay honest in the ledger, and clean
        // inputs skip the upload entirely)
        if self.cfg.sparse {
            if kv_sync.shipped > 0 || r.chain.handles.kv_sparse.is_none() {
                let view = caches.kv_sparse_view()?;
                let (buf, lit) = self.rt.upload_tensor_view(&view)?;
                r.chain.handles.kv_sparse = Some(UploadHandle { buf, lit });
            }
        } else if kv_sync.shipped > 0 || r.chain.handles.kv.is_none() {
            let view = caches.kv_view();
            let (buf, lit) = self.rt.upload_tensor_view(&view)?;
            r.chain.handles.kv = Some(UploadHandle { buf, lit });
        }
        let ind_key_ok = matches!(
            &r.chain.handles.ind,
            Some((name, layers, _)) if name == &indicator && layers == &ind_for_exe
        );
        if ind_sync.shipped > 0 || !ind_key_ok {
            // stage the gather only when it is actually uploaded — a
            // reused resident buffer costs zero host work
            caches.gather_ind_into(&indicator, &ind_for_exe, &mut r.ind_gather)?;
            let (buf, lit) = self.rt.upload_tensor_view(&r.ind_gather.view())?;
            r.chain.handles.ind =
                Some((indicator.clone(), ind_for_exe.clone(), UploadHandle { buf, lit }));
        }
        let conf_key_ok = matches!(
            &r.chain.handles.conf,
            Some((for_slots, _)) if for_slots.as_slice() == slots
        );
        if conf_sync.shipped > 0 || !conf_key_ok {
            caches.conf_masked_into(slots, &mut r.conf_masked)?;
            let (buf, lit) =
                self.rt.upload_tensor_view(&r.conf_masked.view())?;
            r.chain.handles.conf = Some((slots.to_vec(), UploadHandle { buf, lit }));
        }

        let start_t = HostTensor::scalar_i32(block_start as i32);
        let alpha_t = HostTensor::scalar_f32(self.cfg.alpha);
        let kv_buf = if self.cfg.sparse {
            &r.chain.handles.kv_sparse.as_ref().expect("kv handle").buf
        } else {
            &r.chain.handles.kv.as_ref().expect("kv handle").buf
        };
        let ind_buf = &r.chain.handles.ind.as_ref().expect("ind handle").2.buf;
        let conf_buf = &r.chain.handles.conf.as_ref().expect("conf handle").1.buf;
        let args = [
            ExecArg::Host(r.step_tokens.view()),
            ExecArg::Host(start_t.view()),
            ExecArg::Device(kv_buf),
            ExecArg::Device(ind_buf),
            // occupancy mask: rows not in `slots` can never win importance
            ExecArg::Device(conf_buf),
            ExecArg::Host(alpha_t.view()),
        ];
        let out = self.rt.run_args(&self.arch, exe, &self.cfg.checkpoint, &args)?;
        // outputs: logits [B,k,V], pos [B,k], kv_block, ind_block
        caches.merge_step_logits_slots(&out[0], &out[1], slots)?;
        if self.cfg.sparse {
            caches.scatter_kv_block_sparse_slots(block_start, block, &out[2], slots)?;
        } else {
            caches.scatter_kv_block_slots(block_start, block, &out[2], slots)?;
        }
        caches.scatter_ind_block_slots(
            &indicator,
            &ind_for_exe,
            block_start,
            block,
            &out[3],
            slots,
        )?;
        r.note_step_applied(caches, &indicator, self.cfg.sparse, block_start, block, slots);
        self.flush_transfer();
        // adaptive-ratio signal: mean |Δconf| over the stepped rows' block
        if let Some(before) = conf_before {
            let block_lo = block_start - d.prompt_len;
            self.update_drift(caches, &before, slots, block_lo, block_lo + block);
        }
        Ok(())
    }

    /// Device-apply prefill: the `prefill_apply` executable regenerates
    /// the refreshed slots' KV/indicator/confidence rows in-graph
    /// (row-filtered by the batch-bit refresh mask) and its cache
    /// outputs are retained on device (donated in place when the
    /// artifacts carry the alias config); the host downloads only the
    /// gen-region logit slice the sampler reads — `logits_gen`
    /// `[B, gen, V]`, never the `[B, ctx, V]` full context. The first
    /// call of a chain seeds the resident tensors from the host mirrors
    /// — the only whole-cache upload of a generation.
    fn prefill_device_impl(
        &mut self,
        tokens: &[i32],
        slots: &[usize],
        caches: &mut GroupCaches,
    ) -> Result<()> {
        let batch = caches.batch;
        let live = self.residents[&batch].live_ctx();
        // sync accounting shared with the sim planner (byte-exact parity)
        self.residents
            .get_mut(&batch)
            .expect("activated")
            .sync_prefill_device(caches, "h", tokens, slots)?;
        // tiered uplink slice ([B, live] token columns), then (re)seed
        // any cold chain handles at the dispatch shapes
        let tok_tier = self.tier_tokens(batch, live)?;
        self.seed_chain(batch, live, caches)?;
        let exe =
            self.arch.exe(&self.arch.tier_exe_name(&prefill_apply_exe_name(batch), live))?;
        debug_assert_eq!(exe.kind, ExeKind::PrefillApply);
        let retain = exe.retain_flags();
        let r = self.residents.get_mut(&batch).expect("activated");
        let kv_buf = &r.chain.handles.kv_chain.as_ref().expect("just seeded").buf;
        let ind_buf = &r.chain.handles.ind_chain.as_ref().expect("just seeded").buf;
        let conf_buf = &r.chain.handles.conf_chain.as_ref().expect("just seeded").buf;
        let args = [
            ExecArg::Host(match &tok_tier {
                Some(t) => t.view(),
                None => r.prefill_tokens.view(),
            }),
            ExecArg::Device(kv_buf),
            ExecArg::Device(ind_buf),
            ExecArg::Device(conf_buf),
            // refresh mask: which rows this prefill regenerates
            ExecArg::Host(r.occ_mask.view()),
        ];
        let mut out =
            self.rt.run_retained(&self.arch, exe, &self.cfg.checkpoint, &args, &retain)?;
        // host mirror refresh from the gen-region logit slice — the only
        // download of a grounding prefill (the prompt rows stay on
        // device); confidence is recomputed from the same rows the
        // device conf merge used
        let logits_i = exe.output_index("logits_gen")?;
        let lg = out.host_at(logits_i, "logits_gen")?;
        if live < self.arch.dims.ctx {
            caches.merge_gen_logits_prefix_slots(lg, live - self.arch.dims.prompt_len, slots)?;
        } else {
            caches.merge_gen_logits_slots(lg, slots)?;
        }
        // chain the retained outputs; the previous buffers drop here, so
        // device memory stays bounded at one live copy per tensor
        r.chain.handles.kv_chain = Some(UploadHandle {
            buf: out.take_retained(exe.output_index("kv")?, "kv")?,
            lit: None,
        });
        r.chain.handles.ind_chain = Some(UploadHandle {
            buf: out.take_retained(exe.output_index("ind")?, "ind")?,
            lit: None,
        });
        r.chain.handles.conf_chain = Some(UploadHandle {
            buf: out.take_retained(exe.output_index("conf")?, "conf")?,
            lit: None,
        });
        r.note_prefill_applied(caches, slots);
        self.flush_transfer();
        Ok(())
    }

    /// Block-sliced device-apply prefill (`prefill_apply_blk*`): like
    /// [`PjrtBackend::prefill_device_impl`], but the executable gathers
    /// each row's CURRENT block window of gen logits in-graph from the
    /// per-row `blk_start` uplink and downloads `logits_blk`
    /// `[B, block, V]` instead of the whole gen region — the only rows
    /// the unmask decision can read. Cache outputs chain identically.
    fn prefill_device_blk_impl(
        &mut self,
        tokens: &[i32],
        slots: &[usize],
        block_starts: &[usize],
        block: usize,
        caches: &mut GroupCaches,
    ) -> Result<()> {
        let batch = caches.batch;
        let live = self.residents[&batch].live_ctx();
        // planner parity with the sim: the blk variant additionally
        // uplinks the [B] blk_start vector and downlinks block-sized
        // logit rows
        self.residents
            .get_mut(&batch)
            .expect("activated")
            .sync_prefill_device_blk(caches, "h", tokens, slots, block)?;
        let tok_tier = self.tier_tokens(batch, live)?;
        self.seed_chain(batch, live, caches)?;
        let exe = self
            .arch
            .exe(&self.arch.tier_exe_name(&prefill_apply_blk_exe_name(block, batch), live))?;
        debug_assert_eq!(exe.kind, ExeKind::PrefillApply);
        let retain = exe.retain_flags();
        let starts_t = HostTensor::I32 {
            shape: vec![batch],
            data: block_starts.iter().map(|&g0| g0 as i32).collect(),
        };
        let r = self.residents.get_mut(&batch).expect("activated");
        let kv_buf = &r.chain.handles.kv_chain.as_ref().expect("just seeded").buf;
        let ind_buf = &r.chain.handles.ind_chain.as_ref().expect("just seeded").buf;
        let conf_buf = &r.chain.handles.conf_chain.as_ref().expect("just seeded").buf;
        let args = [
            ExecArg::Host(match &tok_tier {
                Some(t) => t.view(),
                None => r.prefill_tokens.view(),
            }),
            ExecArg::Device(kv_buf),
            ExecArg::Device(ind_buf),
            ExecArg::Device(conf_buf),
            // refresh mask: which rows this prefill regenerates
            ExecArg::Host(r.occ_mask.view()),
            ExecArg::Host(starts_t.view()),
        ];
        let mut out =
            self.rt.run_retained(&self.arch, exe, &self.cfg.checkpoint, &args, &retain)?;
        let logits_i = exe.output_index("logits_blk")?;
        caches.merge_gen_logits_block_slots(
            out.host_at(logits_i, "logits_blk")?,
            block_starts,
            block,
            slots,
        )?;
        r.chain.handles.kv_chain = Some(UploadHandle {
            buf: out.take_retained(exe.output_index("kv")?, "kv")?,
            lit: None,
        });
        r.chain.handles.ind_chain = Some(UploadHandle {
            buf: out.take_retained(exe.output_index("ind")?, "ind")?,
            lit: None,
        });
        r.chain.handles.conf_chain = Some(UploadHandle {
            buf: out.take_retained(exe.output_index("conf")?, "conf")?,
            lit: None,
        });
        r.note_prefill_applied(caches, slots);
        self.flush_transfer();
        Ok(())
    }

    /// Device-apply step: chains the retained kv/ind/conf outputs of the
    /// previous call straight back as inputs (zero cache bytes in either
    /// direction), ships only the block tokens + batch-bit occupancy
    /// mask, and downloads only the sampled logit rows.
    fn step_device_impl(
        &mut self,
        plan: StepPlan,
        tokens: &[i32],
        block_start: usize,
        block: usize,
        slots: &[usize],
        caches: &mut GroupCaches,
    ) -> Result<()> {
        let batch = caches.batch;
        let live = self.residents[&batch].live_ctx();
        let exe_name =
            self.arch.tier_exe_name(&apply_step_exe_name(plan, self.cfg.block, batch), live);
        let exe = self.arch.exe(&exe_name)?;
        debug_assert_eq!(exe.kind, ExeKind::StepApply);
        // layers the equivalent Host-apply step would download in its
        // ind_block output (the d2h_bytes_avoided baseline)
        let n_ind = if exe.skip.is_empty() {
            self.arch.dims.n_layers
        } else {
            exe.skip_layers.len()
        };
        // selected logit rows this executable downloads (final_keep: the
        // whole block for a dual step, the skip survivors for ES)
        let n_sel = exe.final_keep.unwrap_or(block);
        // shared planner sync (parity with the sim ledger): refuses to
        // run against an unseeded chain or host-divergent slot rows
        let r = self.residents.get_mut(&batch).expect("activated");
        r.sync_step_device(caches, "h", n_ind, n_sel, tokens, block_start, block, slots)?;
        let chain_missing = || anyhow!("device-apply chain missing despite seeded planner");
        let kv_buf =
            &r.chain.handles.kv_chain.as_ref().ok_or_else(chain_missing)?.buf;
        let ind_buf =
            &r.chain.handles.ind_chain.as_ref().ok_or_else(chain_missing)?.buf;
        let conf_buf =
            &r.chain.handles.conf_chain.as_ref().ok_or_else(chain_missing)?.buf;
        let start_t = HostTensor::scalar_i32(block_start as i32);
        let alpha_t = HostTensor::scalar_f32(self.cfg.alpha);
        let retain = exe.retain_flags();
        let args = [
            ExecArg::Host(r.step_tokens.view()),
            ExecArg::Host(start_t.view()),
            ExecArg::Device(kv_buf),
            ExecArg::Device(ind_buf),
            ExecArg::Device(conf_buf),
            // batch-bit occupancy mask: vacant rows can never win the
            // in-graph importance selection
            ExecArg::Host(r.occ_mask.view()),
            ExecArg::Host(alpha_t.view()),
        ];
        let mut out =
            self.rt.run_retained(&self.arch, exe, &self.cfg.checkpoint, &args, &retain)?;
        // the only D2H traffic: the sampled logit rows (+ positions)
        let logits_i = exe.output_index("logits")?;
        let pos_i = exe.output_index("pos")?;
        caches.merge_step_logits_slots(
            out.host_at(logits_i, "logits")?,
            out.host_at(pos_i, "pos")?,
            slots,
        )?;
        r.chain.handles.kv_chain = Some(UploadHandle {
            buf: out.take_retained(exe.output_index("kv")?, "kv")?,
            lit: None,
        });
        r.chain.handles.ind_chain = Some(UploadHandle {
            buf: out.take_retained(exe.output_index("ind")?, "ind")?,
            lit: None,
        });
        r.chain.handles.conf_chain = Some(UploadHandle {
            buf: out.take_retained(exe.output_index("conf")?, "conf")?,
            lit: None,
        });
        r.note_step_applied(caches, "h", false, block_start, block, slots);
        self.flush_transfer();
        Ok(())
    }

    /// Fused device-apply step: one `step_apply_k` execution runs `k`
    /// ES iterations in-graph — the host sampler rule replicated
    /// between inner iterations (highest-confidence masked block
    /// position, last max on ties, EOS guard, argmax caches seeded
    /// from the host logits mirror via the `tok_seed` uplink),
    /// confidence recomputed in-graph each time — chains the retained
    /// kv/ind/conf outputs exactly like the single-step path, and
    /// downloads the FINAL iteration's selected logit rows plus each
    /// inner iteration's committed position and token
    /// (`commit_pos`/`commit_tok`, returned for the scheduler to apply
    /// verbatim) and the per-slot committed-count vector, which is
    /// audited here: a greedy fused run must commit exactly one token
    /// per inner iteration per dispatched slot, and any other count
    /// means the in-graph unmask diverged from the contract the chain
    /// was built on — the caller invalidates the chain on the error.
    fn step_device_k_impl(
        &mut self,
        k: usize,
        tokens: &[i32],
        block_start: usize,
        block: usize,
        slots: &[usize],
        caches: &mut GroupCaches,
    ) -> Result<FusedCommits> {
        let batch = caches.batch;
        let live = self.residents[&batch].live_ctx();
        let exe = self
            .arch
            .exe(&self.arch.tier_exe_name(&fused_step_exe_name(k, self.cfg.block, batch), live))?;
        debug_assert_eq!(exe.kind, ExeKind::StepApplyK);
        let n_ind = if exe.skip.is_empty() {
            self.arch.dims.n_layers
        } else {
            exe.skip_layers.len()
        };
        let n_sel = exe.final_keep.unwrap_or(block);
        let (mask, eos) = (self.rt.tokenizer.mask, self.rt.tokenizer.eos);
        // shared planner sync (parity with the sim's fused ledger):
        // one uplink, k in-graph confidence steps, one downlink
        let r = self.residents.get_mut(&batch).expect("activated");
        r.sync_step_device_k(caches, "h", n_ind, n_sel, k, tokens, block_start, block, slots)?;
        r.stage_tok_seed(caches, block_start, block, slots, mask, eos);
        let chain_missing = || anyhow!("device-apply chain missing despite seeded planner");
        let kv_buf =
            &r.chain.handles.kv_chain.as_ref().ok_or_else(chain_missing)?.buf;
        let ind_buf =
            &r.chain.handles.ind_chain.as_ref().ok_or_else(chain_missing)?.buf;
        let conf_buf =
            &r.chain.handles.conf_chain.as_ref().ok_or_else(chain_missing)?.buf;
        let start_t = HostTensor::scalar_i32(block_start as i32);
        let alpha_t = HostTensor::scalar_f32(self.cfg.alpha);
        // greedy-only dispatch: an impossible confidence threshold makes
        // the in-graph unmask commit exactly the greedy winner per inner
        // iteration, matching the host sampler under the eligibility gate
        let threshold_t = HostTensor::scalar_f32(2.0);
        let retain = exe.retain_flags();
        let args = [
            ExecArg::Host(r.step_tokens.view()),
            ExecArg::Host(start_t.view()),
            ExecArg::Device(kv_buf),
            ExecArg::Device(ind_buf),
            ExecArg::Device(conf_buf),
            ExecArg::Host(r.occ_mask.view()),
            ExecArg::Host(alpha_t.view()),
            ExecArg::Host(threshold_t.view()),
            ExecArg::Host(r.tok_seed.view()),
        ];
        let mut out =
            self.rt.run_retained(&self.arch, exe, &self.cfg.checkpoint, &args, &retain)?;
        let logits_i = exe.output_index("logits")?;
        let pos_i = exe.output_index("pos")?;
        caches.merge_step_logits_slots(
            out.host_at(logits_i, "logits")?,
            out.host_at(pos_i, "pos")?,
            slots,
        )?;
        // audit the in-graph commits: greedy fuses commit exactly one
        // token per inner iteration per occupied slot
        let committed = out
            .host_at(exe.output_index("committed")?, "committed")?
            .as_i32()?
            .to_vec();
        for &s in slots {
            let got = *committed.get(s).ok_or_else(|| {
                anyhow!("committed vector too short for slot {s} ({exe_n})",
                        exe_n = exe.name)
            })?;
            if got != k as i32 {
                return Err(anyhow::Error::new(PoisonedChain(format!(
                    "fused run {exe_n} committed {got} tokens for slot {s}, \
                     expected exactly {k} (one per inner iteration); the \
                     in-graph unmask diverged from the greedy contract",
                    exe_n = exe.name
                ))));
            }
        }
        // the per-iteration commit transcript [B, k] i32 × 2 — convert
        // block-relative positions to gen-region positions
        let commit_pos = out
            .host_at(exe.output_index("commit_pos")?, "commit_pos")?
            .as_i32()?
            .to_vec();
        let commit_tok = out
            .host_at(exe.output_index("commit_tok")?, "commit_tok")?
            .as_i32()?
            .to_vec();
        let g0 = block_start - self.arch.dims.prompt_len;
        let mut fused = FusedCommits::with_capacity(slots.len());
        for &s in slots {
            let mut row = Vec::with_capacity(k);
            for i in 0..k {
                let rel = commit_pos[s * k + i];
                if rel < 0 || rel as usize >= block {
                    return Err(anyhow!(
                        "fused run {exe_n} slot {s} iteration {i}: commit \
                         position {rel} outside block of {block}",
                        exe_n = exe.name
                    ));
                }
                row.push((g0 + rel as usize, commit_tok[s * k + i]));
            }
            fused.push(row);
        }
        r.chain.handles.kv_chain = Some(UploadHandle {
            buf: out.take_retained(exe.output_index("kv")?, "kv")?,
            lit: None,
        });
        r.chain.handles.ind_chain = Some(UploadHandle {
            buf: out.take_retained(exe.output_index("ind")?, "ind")?,
            lit: None,
        });
        r.chain.handles.conf_chain = Some(UploadHandle {
            buf: out.take_retained(exe.output_index("conf")?, "conf")?,
            lit: None,
        });
        r.note_step_applied(caches, "h", false, block_start, block, slots);
        self.flush_transfer();
        Ok(fused)
    }
}

#[cfg(test)]
mod tests {
    use super::sim::{SimBackend, SimCfg};
    use super::*;

    fn sched(n_slots: usize, method: Method, block: usize) -> GroupScheduler<'static> {
        let backend = SimBackend::new(SimCfg::default());
        let cfg = SchedCfg {
            method,
            block,
            refresh: RefreshPolicy { prompt_period: 16, block_period: 2 },
            sampler: SamplerCfg::llada(),
            seed: 0,
            k: 1,
            hysteresis: None,
        };
        GroupScheduler::new(Box::new(backend), n_slots, cfg).unwrap()
    }

    /// Fusion-friendly cadence: block 8 with block_period 4 schedules
    /// [P, E, E, E, D, E, E, E] per block — two 3-iteration ES runs
    /// that a k ≥ 2 config fuses.
    fn sched_fused(n_slots: usize, k: usize) -> GroupScheduler<'static> {
        let backend = SimBackend::new(SimCfg::default());
        let cfg = SchedCfg {
            method: Method::EsDllm,
            block: 8,
            refresh: RefreshPolicy { prompt_period: 16, block_period: 4 },
            sampler: SamplerCfg::llada(),
            seed: 0,
            k,
            hysteresis: None,
        };
        GroupScheduler::new(Box::new(backend), n_slots, cfg).unwrap()
    }

    fn input(id: u64, prompt: &str, params: SeqParams) -> SeqInput {
        SeqInput {
            id,
            prompt: prompt.to_string(),
            params,
            submitted: Instant::now(),
        }
    }

    fn run_to_drain(s: &mut GroupScheduler<'_>) -> Vec<FinishedSeq> {
        let mut out = Vec::new();
        let mut guard = 0;
        while s.active() > 0 {
            out.extend(s.tick().unwrap());
            guard += 1;
            assert!(guard < 1000, "scheduler failed to drain");
        }
        out
    }

    #[test]
    fn echo_completes_with_eos_guard_early_retire() {
        // SimBackend echoes the prompt then EOS-fills; "ab" needs only
        // block 0 of the gen region, so the EOS guard must retire the
        // sequence at the first block boundary, not after all 8 ticks.
        let mut s = sched(1, Method::EsDllm, 4);
        s.admit(input(7, "ab", SeqParams::default())).unwrap();
        let done = run_to_drain(&mut s);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, 7);
        assert_eq!(done[0].text, "ab");
        assert_eq!(done[0].iterations, 4, "block 0 only: 4 greedy unmasks");
        assert_eq!(done[0].tokens, 4, "a, b, and two EOS fills");
        assert_eq!(s.ticks, 4);
    }

    #[test]
    fn overdue_sequence_retires_at_block_boundary_with_timeout_error() {
        // per-tick sleeps guarantee the 1 ms deadline passes long before
        // the 8-content-char prompt's two blocks complete; the sequence
        // must retire at the FIRST block boundary with a structured
        // timeout error, freeing the slot
        let backend = SimBackend::new(SimCfg::default().with_costs(2000, 1000, 1000));
        let cfg = SchedCfg {
            method: Method::EsDllm,
            block: 4,
            refresh: RefreshPolicy { prompt_period: 16, block_period: 2 },
            sampler: SamplerCfg::llada(),
            seed: 0,
            k: 1,
            hysteresis: None,
        };
        let mut s = GroupScheduler::new(Box::new(backend), 1, cfg).unwrap();
        let params = SeqParams { timeout_ms: Some(1), ..Default::default() };
        s.admit(input(9, "abcdefgh", params)).unwrap();
        let mut done = Vec::new();
        for _ in 0..4 {
            done.extend(s.tick().unwrap());
        }
        assert_eq!(done.len(), 1, "retired at the first block boundary");
        let err = done[0].error.as_deref().expect("structured timeout error");
        assert!(err.starts_with("timeout:"), "unexpected error: {err}");
        assert_eq!(done[0].iterations, 4, "block 0 only");
        assert_eq!(s.active(), 0, "slot freed for the queue");
        // a zero deadline is a bad request, not a served timeout
        let zero = SeqParams { timeout_ms: Some(0), ..Default::default() };
        let e = s.admit(input(10, "ab", zero)).unwrap_err().to_string();
        assert!(e.starts_with("bad request:"), "{e}");
    }

    #[test]
    fn completed_sequence_beats_its_deadline_at_the_same_boundary() {
        // "ab" finishes via the EOS guard at block 0's boundary; even
        // with the deadline long expired the finished result is
        // delivered — completed work is never converted to a timeout
        let backend = SimBackend::new(SimCfg::default().with_costs(2000, 1000, 1000));
        let cfg = SchedCfg {
            method: Method::EsDllm,
            block: 4,
            refresh: RefreshPolicy { prompt_period: 16, block_period: 2 },
            sampler: SamplerCfg::llada(),
            seed: 0,
            k: 1,
            hysteresis: None,
        };
        let mut s = GroupScheduler::new(Box::new(backend), 1, cfg).unwrap();
        let params = SeqParams { timeout_ms: Some(1), ..Default::default() };
        s.admit(input(11, "ab", params)).unwrap();
        let done = run_to_drain(&mut s);
        assert_eq!(done.len(), 1);
        assert!(done[0].error.is_none(), "finished result delivered");
        assert_eq!(done[0].text, "ab");
    }

    #[test]
    fn demote_fused_k_steps_down_to_unfused() {
        let mut s = sched_fused(1, 8);
        assert_eq!(s.fused_k(), 8);
        assert_eq!(s.demote_fused_k(), Some(4));
        assert_eq!(s.demote_fused_k(), Some(2));
        assert_eq!(s.demote_fused_k(), Some(1));
        assert_eq!(s.demote_fused_k(), None, "already unfused");
        assert_eq!(s.fused_k(), 1);
    }

    #[test]
    fn reground_after_failed_tick_is_token_identical() {
        // baseline: fault-free run
        let mut clean = sched(2, Method::EsDllm, 4);
        clean.admit(input(1, "abcdef", SeqParams::default())).unwrap();
        clean.admit(input(2, "wxyz", SeqParams::default())).unwrap();
        let mut want = run_to_drain(&mut clean);
        want.sort_by_key(|f| f.id);

        // faulted: the 3rd executable run fails mid-generation; the
        // recovery protocol (re-ground + re-tick) must reproduce the
        // fault-free outputs exactly
        let backend = SimBackend::new(
            SimCfg::default()
                .with_faults(crate::fault::FaultPlan::parse("exec@3").unwrap()),
        );
        let cfg = SchedCfg {
            method: Method::EsDllm,
            block: 4,
            refresh: RefreshPolicy { prompt_period: 16, block_period: 2 },
            sampler: SamplerCfg::llada(),
            seed: 0,
            k: 1,
            hysteresis: None,
        };
        let mut s = GroupScheduler::new(Box::new(backend), 2, cfg).unwrap();
        s.admit(input(1, "abcdef", SeqParams::default())).unwrap();
        s.admit(input(2, "wxyz", SeqParams::default())).unwrap();
        let mut got = Vec::new();
        let mut guard = 0;
        let mut retried = 0;
        while s.active() > 0 {
            match s.tick() {
                Ok(f) => got.extend(f),
                Err(e) => {
                    assert_eq!(
                        crate::fault::classify(&e),
                        crate::fault::TickErrorClass::Transient
                    );
                    s.reground_active().unwrap();
                    retried += 1;
                }
            }
            guard += 1;
            assert!(guard < 1000, "failed to drain");
        }
        assert_eq!(retried, 1, "exactly one faulted tick");
        got.sort_by_key(|f| f.id);
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.id, w.id);
            assert_eq!(g.text, w.text, "recovered output must be token-identical");
            assert_eq!(g.tokens, w.tokens);
            assert!(g.error.is_none());
        }
    }

    #[test]
    fn multi_block_echo_and_plan_cadence() {
        let mut s = sched(1, Method::EsDllm, 4);
        // 6 content chars: block 0 full, block 1 = 2 content + 2 EOS
        s.admit(input(1, "abcdef", SeqParams::default())).unwrap();
        let done = run_to_drain(&mut s);
        assert_eq!(done[0].text, "abcdef");
        assert_eq!(done[0].iterations, 8);
        // per block of 4 with block_period 2: prefill, es, dual, es
        assert_eq!(done[0].n_prefill, 2);
        assert_eq!(done[0].n_dual, 2);
        assert_eq!(done[0].n_es, 4);
        assert_eq!((s.n_prefill, s.n_dual, s.n_es), (2, 2, 4));
    }

    #[test]
    fn vanilla_runs_one_full_forward_per_tick() {
        let mut s = sched(2, Method::Vanilla, 4);
        s.admit(input(1, "ab", SeqParams::default())).unwrap();
        s.admit(input(2, "cd", SeqParams::default())).unwrap();
        let done = run_to_drain(&mut s);
        assert_eq!(done.len(), 2);
        assert_eq!(s.n_prefill, s.ticks, "one shared vanilla forward per tick");
        assert_eq!(s.n_dual + s.n_es, 0);
    }

    #[test]
    fn retirement_frees_slot_for_next_admission() {
        let mut s = sched(1, Method::EsDllm, 4);
        s.admit(input(1, "ab", SeqParams::default())).unwrap();
        // group full: second admission must be refused
        assert!(s.admit(input(2, "xy", SeqParams::default())).is_err());
        let first = run_to_drain(&mut s);
        assert_eq!(first[0].id, 1);
        // the retired block boundary freed the slot
        assert_eq!(s.free_slots(), 1);
        s.admit(input(2, "xy", SeqParams::default())).unwrap();
        let second = run_to_drain(&mut s);
        assert_eq!(second[0].id, 2);
        assert_eq!(second[0].text, "xy");
    }

    #[test]
    fn mid_flight_admission_is_trajectory_exact() {
        // B's output when admitted into a running group mid-flight must
        // equal B's output in a solo run: row-filtered merges make slot
        // trajectories independent.
        let mut solo = sched(2, Method::EsDllm, 4);
        solo.admit(input(9, "xy", SeqParams::default())).unwrap();
        let solo_done = run_to_drain(&mut solo);

        let mut s = sched(2, Method::EsDllm, 4);
        s.admit(input(1, "abcdefg", SeqParams::default())).unwrap();
        // step A into the middle of its first block...
        s.tick().unwrap();
        s.tick().unwrap();
        // ...then admit B into the free slot while A is running
        s.admit(input(2, "xy", SeqParams::default())).unwrap();
        assert_eq!(s.active(), 2);
        let done = run_to_drain(&mut s);
        let a = done.iter().find(|f| f.id == 1).unwrap();
        let b = done.iter().find(|f| f.id == 2).unwrap();
        assert_eq!(a.text, "abcdefg");
        assert_eq!(b.text, "xy");
        assert_eq!(b.text, solo_done[0].text);
        assert_eq!(b.iterations, solo_done[0].iterations);
        // B retired before A: its slot freed at an earlier boundary
        assert!(b.iterations < a.iterations);
    }

    #[test]
    fn per_request_gen_len_truncates() {
        let mut s = sched(1, Method::EsDllm, 4);
        let params = SeqParams { gen_len: Some(4), ..Default::default() };
        s.admit(input(1, "abcdefgh", params)).unwrap();
        let done = run_to_drain(&mut s);
        assert_eq!(done[0].text, "abcd", "one block of 4 only");
        assert_eq!(done[0].tokens, 4);
        assert_eq!(done[0].iterations, 4);
    }

    #[test]
    fn admit_validates_params() {
        let mut s = sched(1, Method::EsDllm, 4);
        let bad_len = SeqParams { gen_len: Some(3), ..Default::default() };
        let err = s.admit(input(1, "ab", bad_len)).unwrap_err();
        assert!(format!("{err}").starts_with("bad request:"), "{err}");
        let bad_temp = SeqParams { temperature: Some(-1.0), ..Default::default() };
        assert!(s.admit(input(1, "ab", bad_temp)).is_err());
        let bad_th = SeqParams { parallel_threshold: Some(1.5), ..Default::default() };
        assert!(s.admit(input(1, "ab", bad_th)).is_err());
        let unknown_char = SeqParams::default();
        assert!(s.admit(input(1, "Ü", unknown_char)).is_err());
        // valid request still admits after the failures
        s.admit(input(2, "ok", SeqParams::default())).unwrap();
    }

    #[test]
    fn parallel_threshold_override_speeds_decode() {
        let mut greedy = sched(1, Method::EsDllm, 4);
        greedy.admit(input(1, "abcdef", SeqParams::default())).unwrap();
        let g = run_to_drain(&mut greedy);
        let mut pd = sched(1, Method::EsDllm, 4);
        let params = SeqParams { parallel_threshold: Some(0.5), ..Default::default() };
        pd.admit(input(1, "abcdef", params)).unwrap();
        let p = run_to_drain(&mut pd);
        assert_eq!(g[0].text, p[0].text);
        assert!(
            p[0].iterations < g[0].iterations,
            "parallel decoding {} !< greedy {}",
            p[0].iterations,
            g[0].iterations
        );
    }

    // Resident-cache transfer acceptance (zero steady-state KV upload,
    // admission invalidation, ledger-vs-bitmap deltas) lives in
    // tests/transfer_accounting.rs to avoid duplicate maintenance.

    fn sched_classes(classes: &[usize], block: usize) -> GroupScheduler<'static> {
        let backend = SimBackend::new(SimCfg::default());
        let cfg = SchedCfg {
            method: Method::EsDllm,
            block,
            refresh: RefreshPolicy { prompt_period: 16, block_period: 2 },
            sampler: SamplerCfg::llada(),
            seed: 0,
            k: 1,
            hysteresis: None,
        };
        GroupScheduler::with_classes(Box::new(backend), classes, cfg).unwrap()
    }

    #[test]
    fn select_class_picks_smallest_fit() {
        let s = sched_classes(&[1, 8], 4);
        assert_eq!(s.classes(), &[1, 8]);
        assert_eq!(s.batch_class(), 8, "starts at full capacity");
        assert_eq!(s.select_class(0), 1, "idle sizes down to the lone class");
        assert_eq!(s.select_class(1), 1);
        assert_eq!(s.select_class(2), 8);
        assert_eq!(s.select_class(8), 8);
        assert_eq!(s.select_class(20), 8, "overload caps at the largest class");
    }

    #[test]
    fn switch_refused_mid_block_and_when_sequences_cannot_fit() {
        let mut s = sched_classes(&[2, 8], 4);
        assert!(s.maybe_switch_class(1).unwrap(), "idle switch is free");
        assert_eq!(s.batch_class(), 2);
        s.admit(input(1, "abcdefgh", SeqParams::default())).unwrap();
        s.tick().unwrap();
        // mid-block (i_b == 1): a switch would corrupt the trajectory
        assert!(!s.at_block_boundary());
        assert!(!s.maybe_switch_class(8).unwrap());
        assert_eq!(s.batch_class(), 2);
        // run to the block boundary: now the upshift goes through
        while !s.at_block_boundary() {
            s.tick().unwrap();
        }
        assert!(s.maybe_switch_class(7).unwrap());
        assert_eq!(s.batch_class(), 8);
        // 3 resident sequences keep the demand above the b=2 class, so
        // no downshift can strand them
        s.admit(input(2, "xy", SeqParams::default())).unwrap();
        s.admit(input(3, "pq", SeqParams::default())).unwrap();
        assert_eq!(s.active(), 3);
        assert!(!s.maybe_switch_class(0).unwrap());
        assert_eq!(s.batch_class(), 8);
        let done = run_to_drain(&mut s);
        assert_eq!(done.len(), 3);
    }

    #[test]
    fn class_switch_mid_generation_is_trajectory_exact() {
        // baseline: the same sequence with no switching
        let mut solo = sched(1, Method::EsDllm, 4);
        solo.admit(input(9, "abcdefg", SeqParams::default())).unwrap();
        let base = run_to_drain(&mut solo);

        // switched run: start on b1, upshift to b8 at the first block
        // boundary (a grounding prefill re-grounds the migrated slot in
        // the new class), then downshift back to b1 at the next
        let mut s = sched_classes(&[1, 8], 4);
        assert!(s.maybe_switch_class(1).unwrap());
        s.admit(input(9, "abcdefg", SeqParams::default())).unwrap();
        let mut done = Vec::new();
        let mut flips = 0;
        let mut guard = 0;
        while s.active() > 0 {
            if s.at_block_boundary() && s.active() > 0 {
                let target_queue = if s.batch_class() == 1 { 7 } else { 0 };
                if s.maybe_switch_class(target_queue).unwrap() {
                    flips += 1;
                }
            }
            done.extend(s.tick().unwrap());
            guard += 1;
            assert!(guard < 1000, "failed to drain");
        }
        assert!(flips >= 1, "the workload exercised a real switch");
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].text, base[0].text, "switching must not change output");
        assert_eq!(done[0].iterations, base[0].iterations);
    }

    #[test]
    fn fused_k_decode_is_token_identical_to_k1() {
        // the acceptance criterion: sim decode at k ∈ {2, 4, 8} is
        // token-identical to k = 1 with the same seed, with identical
        // per-sequence counters — only the dispatch counts shrink
        for prompt in ["abcdef", "abcdefghij", "a"] {
            let mut base = sched_fused(2, 1);
            base.admit(input(1, prompt, SeqParams::default())).unwrap();
            let b = run_to_drain(&mut base);
            assert_eq!(base.n_fused, 0, "k = 1 never fuses");
            for k in [2usize, 4, 8] {
                let mut s = sched_fused(2, k);
                s.admit(input(1, prompt, SeqParams::default())).unwrap();
                let f = run_to_drain(&mut s);
                assert_eq!(f[0].text, b[0].text, "k = {k}, prompt {prompt:?}");
                assert_eq!(f[0].iterations, b[0].iterations, "k = {k}");
                assert_eq!(f[0].tokens, b[0].tokens, "k = {k}");
                assert_eq!(f[0].n_prefill, b[0].n_prefill, "k = {k}");
                assert_eq!(f[0].n_dual, b[0].n_dual, "k = {k}");
                assert_eq!(f[0].n_es, b[0].n_es, "per-seq ES iterations, k = {k}");
                assert!(s.n_fused > 0, "k = {k} fused at least one run");
                assert!(
                    s.n_es < base.n_es,
                    "k = {k}: {} ES dispatches !< {} unfused",
                    s.n_es,
                    base.n_es
                );
                assert!(s.ticks < base.ticks, "fused ticks advance multiple iters");
            }
        }
        // cadence sanity for the helper's config: one block of 8 under
        // block_period 4 runs [P, E*3-fused, D, E*3-fused] at k >= 4
        let mut s = sched_fused(1, 4);
        s.admit(input(1, "abcdef", SeqParams::default())).unwrap();
        run_to_drain(&mut s);
        assert_eq!((s.n_prefill, s.n_dual, s.n_es, s.n_fused), (1, 1, 2, 2));
        assert_eq!(s.ticks, 4, "8 iterations in 4 dispatch rounds");
    }

    #[test]
    fn fused_mid_flight_admission_is_trajectory_exact() {
        // the same admission script under k = 1 and k = 4: per-sequence
        // results must match even though the fused run advances several
        // iterations per tick, so B's admission lands on a k-boundary
        // at a different point of A's block
        let run = |k: usize| {
            let mut s = sched_fused(2, k);
            s.admit(input(1, "abcdefghij", SeqParams::default())).unwrap();
            s.tick().unwrap();
            s.tick().unwrap(); // A several iterations in when fused
            s.admit(input(2, "ab", SeqParams::default())).unwrap();
            assert_eq!(s.active(), 2);
            let mut done = run_to_drain(&mut s);
            done.sort_by_key(|f| f.id);
            done
        };
        let base = run(1);
        let fused = run(4);
        assert_eq!(base.len(), 2);
        for (b, f) in base.iter().zip(&fused) {
            assert_eq!(f.id, b.id);
            assert_eq!(f.text, b.text, "seq {}", b.id);
            assert_eq!(f.iterations, b.iterations, "seq {}", b.id);
            assert_eq!(f.tokens, b.tokens);
            assert_eq!(
                (f.n_prefill, f.n_dual, f.n_es),
                (b.n_prefill, b.n_dual, b.n_es),
                "seq {}",
                b.id
            );
        }
    }

    #[test]
    fn fused_runs_respect_sampler_eligibility() {
        // a parallel-threshold request may commit several tokens per
        // iteration — the fused replay would diverge, so such slots
        // must never fuse (and still decode exactly)
        let params = SeqParams { parallel_threshold: Some(0.5), ..Default::default() };
        let mut base = sched_fused(1, 1);
        base.admit(input(1, "abcdef", params)).unwrap();
        let b = run_to_drain(&mut base);
        let mut s = sched_fused(1, 8);
        s.admit(input(1, "abcdef", params)).unwrap();
        let f = run_to_drain(&mut s);
        assert_eq!(s.n_fused, 0, "threshold slots are ineligible");
        assert_eq!(f[0].text, b[0].text);
        assert_eq!(f[0].iterations, b[0].iterations);

        // the in-graph commit rule bakes the EOS guard in, so a
        // guard-off sampler (which may legitimately commit an early
        // EOS the guard would veto) must also stay unfused — and still
        // decode exactly on the single-step path
        let guard_off = SamplerCfg { eos_guard: false, ..SamplerCfg::llada() };
        let mk = |k: usize| {
            let cfg = SchedCfg {
                method: Method::EsDllm,
                block: 8,
                refresh: RefreshPolicy { prompt_period: 16, block_period: 4 },
                sampler: guard_off,
                seed: 0,
                k,
                hysteresis: None,
            };
            GroupScheduler::new(Box::new(SimBackend::new(SimCfg::default())), 1, cfg)
                .unwrap()
        };
        let mut base = mk(1);
        base.admit(input(2, "abcdef", SeqParams::default())).unwrap();
        let b = run_to_drain(&mut base);
        let mut s = mk(8);
        s.admit(input(2, "abcdef", SeqParams::default())).unwrap();
        let f = run_to_drain(&mut s);
        assert_eq!(s.n_fused, 0, "guard-off slots are ineligible");
        assert_eq!(f[0].text, b[0].text);
        assert_eq!(f[0].iterations, b[0].iterations);
    }

    #[test]
    fn switch_hysteresis_reduces_chain_switches_on_burst_trace() {
        // six sequences served back to back over classes {1, 8}; the
        // queue-depth signal the router would report oscillates — a
        // burst is visible while each even sequence runs, gone for the
        // odd ones. Without hysteresis every oscillation flips the
        // class; with it, the EWMA + hold window ride out the lulls.
        let run = |hyst: Option<SwitchHysteresis>| {
            let backend = SimBackend::new(SimCfg::default());
            let cfg = SchedCfg {
                method: Method::EsDllm,
                block: 4,
                refresh: RefreshPolicy { prompt_period: 16, block_period: 2 },
                sampler: SamplerCfg::llada(),
                seed: 0,
                k: 1,
                hysteresis: hyst,
            };
            let mut s = GroupScheduler::with_classes(Box::new(backend), &[1, 8], cfg).unwrap();
            assert!(s.maybe_switch_class(0).unwrap(), "idle sizing to b1");
            let mut tokens = 0usize;
            let mut iters = 0usize;
            for i in 0..6u64 {
                s.admit(input(i + 1, "abcdef", SeqParams::default())).unwrap();
                let mut guard = 0;
                while s.active() > 0 {
                    let queued = if i % 2 == 0 { 7 } else { 0 };
                    s.maybe_switch_class(queued).unwrap();
                    for f in s.tick().unwrap() {
                        tokens += f.tokens;
                        iters += f.iterations;
                    }
                    guard += 1;
                    assert!(guard < 1000, "failed to drain");
                }
            }
            (s.pool_stats().chain_switches, tokens, iters)
        };
        let (plain_switches, plain_tokens, plain_iters) = run(None);
        let (damped_switches, damped_tokens, damped_iters) =
            run(Some(SwitchHysteresis::default()));
        assert_eq!(damped_tokens, plain_tokens, "equal throughput: same tokens");
        assert_eq!(damped_iters, plain_iters, "equal throughput: same iterations");
        assert!(
            damped_switches < plain_switches,
            "hysteresis must cut chain switches: {damped_switches} !< {plain_switches}"
        );
        // the undamped trace thrashes once per burst edge
        assert!(plain_switches >= 5, "the trace exercised real thrash");
    }

    #[test]
    fn seq_complete_rules() {
        let mask = 1;
        let eos = 2;
        assert!(seq_complete(&[5, 6, 2, 1], mask, eos), "EOS with clean prefix");
        assert!(!seq_complete(&[5, 1, 2, 1], mask, eos), "mask before EOS");
        assert!(seq_complete(&[5, 6, 7, 8], mask, eos), "fully unmasked");
        assert!(!seq_complete(&[5, 6, 7, 1], mask, eos), "still masked, no EOS");
    }

    #[test]
    fn preempted_then_resumed_sequence_is_trajectory_exact() {
        // baseline: the victim alone, never preempted
        let mut solo = sched(1, Method::EsDllm, 4);
        solo.admit(input(1, "abcdefgh", SeqParams::default())).unwrap();
        let base = run_to_drain(&mut solo);
        assert_eq!(base.len(), 1);

        // preempted run: decode to the first block boundary, park the
        // victim for a latency-sensitive request, serve that to
        // completion in the freed slot, resume, drain
        let mut s = sched(1, Method::EsDllm, 4);
        s.admit(input(1, "abcdefgh", SeqParams::default())).unwrap();
        for _ in 0..4 {
            assert!(s.tick().unwrap().is_empty(), "two blocks of work remain");
        }
        assert!(s.at_block_boundary());
        // an equal- or lower-class waiter preempts nobody
        assert!(s.preempt_victim(SloClass::Throughput).is_none());
        assert!(s.preempt_victim(SloClass::Batch).is_none());
        assert_eq!(s.preempt_victim(SloClass::LatencySensitive), Some(1));
        assert_eq!(s.active(), 0);
        assert_eq!(s.parked(), 1);
        assert_eq!(s.parked_ids(), vec![1]);
        assert_eq!(s.best_parked_class(), Some(SloClass::Throughput));

        let ls = SeqParams { slo: SloClass::LatencySensitive, ..Default::default() };
        s.admit(input(2, "xy", ls)).unwrap();
        let served = run_to_drain(&mut s);
        assert_eq!(served.len(), 1);
        assert_eq!(served[0].id, 2);
        assert_eq!(served[0].text, "xy");

        match s.resume_victim() {
            ResumeOutcome::Seated(id) => assert_eq!(id, 1),
            other => panic!("expected Seated, got {other:?}"),
        }
        assert_eq!(s.parked(), 0);
        let done = run_to_drain(&mut s);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, 1);
        assert_eq!(done[0].text, base[0].text, "park/resume must not change output");
        assert_eq!(done[0].tokens, base[0].tokens);
        assert_eq!(done[0].iterations, base[0].iterations);
    }

    #[test]
    fn preemption_refuses_a_mid_block_victim() {
        let mut s = sched(1, Method::EsDllm, 4);
        s.admit(input(1, "abcdefgh", SeqParams::default())).unwrap();
        s.tick().unwrap();
        assert!(!s.at_block_boundary(), "one tick in = mid-block");
        assert!(
            s.preempt_victim(SloClass::LatencySensitive).is_none(),
            "a mid-block victim is not a trajectory-exact cut point"
        );
        // at the boundary the same victim becomes eligible
        for _ in 0..3 {
            s.tick().unwrap();
        }
        assert!(s.at_block_boundary());
        assert_eq!(s.preempt_victim(SloClass::LatencySensitive), Some(1));
        s.evict_all();
        assert_eq!(s.parked(), 0, "eviction covers the parked victim");
    }

    #[test]
    fn parked_victim_past_deadline_is_shed_on_resume() {
        let mut s = sched(1, Method::EsDllm, 4);
        let params = SeqParams { timeout_ms: Some(30), ..Default::default() };
        s.admit(input(5, "abcdefgh", params)).unwrap();
        for _ in 0..4 {
            s.tick().unwrap();
        }
        assert_eq!(s.preempt_victim(SloClass::LatencySensitive), Some(5));
        std::thread::sleep(std::time::Duration::from_millis(40));
        match s.resume_victim() {
            ResumeOutcome::Shed(f) => {
                assert_eq!(f.id, 5);
                let err = f.error.expect("structured error");
                assert!(err.starts_with("timeout:"), "{err}");
                assert!(err.contains("(preempted)"), "{err}");
                assert_eq!(f.slo, SloClass::Throughput);
            }
            other => panic!("expected Shed, got {other:?}"),
        }
        assert_eq!(s.parked(), 0);
        assert!(matches!(s.resume_victim(), ResumeOutcome::None));
    }

    /// Tiered sim backend + fusion-friendly cadence (block 8, period 4);
    /// `live` toggles the scheduler's live-context opt-in.
    fn sched_live(n_slots: usize, k: usize, live: bool) -> GroupScheduler<'static> {
        let base = SimCfg::default();
        let tiers = SimCfg::default_ctx_tiers(&base.dims);
        let backend = SimBackend::new(base.with_ctx_tiers(&tiers));
        let cfg = SchedCfg {
            method: Method::EsDllm,
            block: 8,
            refresh: RefreshPolicy { prompt_period: 16, block_period: 4 },
            sampler: SamplerCfg::llada(),
            seed: 0,
            k,
            hysteresis: None,
        };
        let mut s = GroupScheduler::new(Box::new(backend), n_slots, cfg).unwrap();
        s.enable_live_ctx(live);
        s
    }

    #[test]
    fn live_ctx_pruned_decode_is_token_identical() {
        // the tentpole acceptance: a pruned run (dispatches sized to the
        // live frontier, suffix blocks dropped from the attention
        // context) decodes the exact tokens of the full-context run,
        // while every live-row counter shows the saved work
        for prompt in ["abcdef", "abcdefghij", "a"] {
            let mut full = sched_live(2, 1, false);
            full.admit(input(1, prompt, SeqParams::default())).unwrap();
            let f = run_to_drain(&mut full);
            let mut live = sched_live(2, 1, true);
            live.admit(input(1, prompt, SeqParams::default())).unwrap();
            let l = run_to_drain(&mut live);
            assert_eq!(l[0].text, f[0].text, "prompt {prompt:?}");
            assert_eq!(l[0].iterations, f[0].iterations, "prompt {prompt:?}");
            assert_eq!(l[0].tokens, f[0].tokens, "prompt {prompt:?}");
            assert_eq!(
                (l[0].n_prefill, l[0].n_dual, l[0].n_es),
                (f[0].n_prefill, f[0].n_dual, f[0].n_es),
                "prompt {prompt:?}"
            );
            let ls = live.transfer_stats();
            let fs = full.transfer_stats();
            assert!(
                ls.live_row_ticks < ls.full_row_ticks,
                "prompt {prompt:?}: every tick ran below the compiled ctx"
            );
            assert_eq!(
                fs.live_row_ticks, fs.full_row_ticks,
                "tiering off: live rows degenerate to the full context"
            );
            assert!(ls.suffix_blocks_pruned > 0, "prompt {prompt:?}");
            assert_eq!(fs.suffix_blocks_pruned, 0);
            assert!(
                ls.flops_units < fs.flops_units,
                "prompt {prompt:?}: pruned FLOPs {} !< full {}",
                ls.flops_units,
                fs.flops_units
            );
        }
    }

    #[test]
    fn live_ctx_tier_widens_with_the_frontier() {
        // 10 content chars span blocks 0 and 1: the run starts at the
        // smallest tier and widens when block 1 opens. The widening is
        // a counted switch; the initial selection is not.
        let mut s = sched_live(1, 1, true);
        s.admit(input(1, "abcdefghij", SeqParams::default())).unwrap();
        s.tick().unwrap();
        let d = SimCfg::default().dims;
        assert_eq!(s.live_tier(), Some(d.prompt_len + 8), "block 0 tier");
        assert_eq!(s.tier_switches, 0, "first selection is not a switch");
        let done = run_to_drain(&mut s);
        assert_eq!(done[0].text, "abcdefghij");
        assert!(s.tier_switches >= 1, "block 1 widened the tier");
        assert!(s.transfer_stats().early_retired_blocks >= 2, "blocks 2..4 never ran");
    }

    #[test]
    fn live_ctx_early_retirement_prunes_trailing_blocks() {
        // "ab" completes via the EOS guard at block 0's boundary with
        // default gen_len 32 (4 blocks of 8): the 3 trailing blocks are
        // retired wholesale and the tier never moves off the smallest
        let mut s = sched_live(1, 1, true);
        s.admit(input(3, "ab", SeqParams::default())).unwrap();
        let done = run_to_drain(&mut s);
        assert_eq!(done[0].text, "ab");
        assert_eq!(s.tier_switches, 0, "one block of work: no tier motion");
        assert_eq!(s.transfer_stats().early_retired_blocks, 3);
    }

    #[test]
    fn live_ctx_fused_k_pruned_decode_is_token_identical() {
        // fused k > 1 composes with tiering: the fused dispatch runs at
        // the tier's executable and the pruned trajectory still matches
        // the unpruned k = 1 baseline token for token
        let mut base = sched_live(2, 1, false);
        base.admit(input(1, "abcdefghij", SeqParams::default())).unwrap();
        let b = run_to_drain(&mut base);
        for k in [2usize, 4, 8] {
            let mut s = sched_live(2, k, true);
            s.admit(input(1, "abcdefghij", SeqParams::default())).unwrap();
            let f = run_to_drain(&mut s);
            assert_eq!(f[0].text, b[0].text, "k = {k}");
            assert_eq!(f[0].iterations, b[0].iterations, "k = {k}");
            assert_eq!(f[0].tokens, b[0].tokens, "k = {k}");
            assert!(s.n_fused > 0, "k = {k} fused at least one run");
            let ts = s.transfer_stats();
            assert!(ts.suffix_blocks_pruned > 0, "k = {k}");
            assert!(
                ts.flops_units < base.transfer_stats().flops_units,
                "k = {k}: fused + pruned saves FLOPs"
            );
        }
    }

    #[test]
    fn live_ctx_mid_flight_admission_is_trajectory_exact() {
        // the admission script of mid_flight_admission under tiering:
        // A's block-1 frontier holds the tier up while B decodes its
        // block 0, and both outputs match the untier run exactly
        let run = |live: bool| {
            let mut s = sched_live(2, 1, live);
            s.admit(input(1, "abcdefghij", SeqParams::default())).unwrap();
            s.tick().unwrap();
            s.tick().unwrap();
            s.admit(input(2, "ab", SeqParams::default())).unwrap();
            assert_eq!(s.active(), 2);
            let mut done = run_to_drain(&mut s);
            done.sort_by_key(|f| f.id);
            let switches = s.tier_switches;
            (done, switches)
        };
        let (base, _) = run(false);
        let (tiered, switches) = run(true);
        assert_eq!(base.len(), 2);
        for (b, t) in base.iter().zip(&tiered) {
            assert_eq!(t.id, b.id);
            assert_eq!(t.text, b.text, "seq {}", b.id);
            assert_eq!(t.iterations, b.iterations, "seq {}", b.id);
            assert_eq!(t.tokens, b.tokens, "seq {}", b.id);
        }
        assert!(switches >= 1, "A widening to block 1 switched the tier");
    }

    #[test]
    fn live_ctx_preempt_resume_across_tier_switch_is_trajectory_exact() {
        // park the victim at its block-0 boundary, serve an LS request
        // at the narrow tier, then resume: the victim's block 1 widens
        // the tier (a counted switch + grounding prefill) and its output
        // still matches the identical script with tiering off
        let run = |live: bool| {
            let mut s = sched_live(1, 1, live);
            s.admit(input(1, "abcdefghij", SeqParams::default())).unwrap();
            s.tick().unwrap();
            while !s.at_block_boundary() {
                s.tick().unwrap();
            }
            assert_eq!(s.preempt_victim(SloClass::LatencySensitive), Some(1));
            let ls = SeqParams { slo: SloClass::LatencySensitive, ..Default::default() };
            s.admit(input(2, "xy", ls)).unwrap();
            let mut done = run_to_drain(&mut s);
            match s.resume_victim() {
                ResumeOutcome::Seated(id) => assert_eq!(id, 1),
                other => panic!("expected Seated, got {other:?}"),
            }
            done.extend(run_to_drain(&mut s));
            done.sort_by_key(|f| f.id);
            let switches = s.tier_switches;
            (done, switches)
        };
        let (base, _) = run(false);
        let (tiered, switches) = run(true);
        assert_eq!(base.len(), 2);
        for (b, t) in base.iter().zip(&tiered) {
            assert_eq!(t.id, b.id);
            assert_eq!(t.text, b.text, "seq {}", b.id);
            assert_eq!(t.iterations, b.iterations, "seq {}", b.id);
            assert_eq!(t.tokens, b.tokens, "seq {}", b.id);
        }
        assert!(switches >= 1, "the resumed block 1 widened the tier");
    }

    #[test]
    fn live_ctx_per_request_gen_len_caps_the_frontier() {
        // a gen_len-8 request never opens block 1, so its frontier (and
        // the dispatched tier) stays at the smallest rung even though
        // the compiled maximum is 4 blocks wider
        let mut s = sched_live(1, 1, true);
        let params = SeqParams { gen_len: Some(8), ..Default::default() };
        s.admit(input(1, "abcdefghijkl", params)).unwrap();
        s.tick().unwrap();
        let d = SimCfg::default().dims;
        assert_eq!(s.live_tier(), Some(d.prompt_len + 8));
        let done = run_to_drain(&mut s);
        assert_eq!(done[0].text, "abcdefgh", "truncated at gen_len");
        assert_eq!(s.tier_switches, 0, "the cap pinned the tier");
    }
}
