"""Layer-2 JAX model: masked-diffusion transformer forward functions.

Implements the paper's inference procedures as pure, AOT-lowerable
functions over an explicit parameter list + explicit caches:

  * ``prefill``        — full forward over all ctx positions; initializes
                         KV caches, indicator caches (hidden/Q/K/V at the
                         skip layers) and the sparse-attention mass (also
                         serves as the *vanilla* per-iteration step and as
                         the prompt-refresh pass).
  * ``step``           — one decode iteration over the current block with
                         optional early-skipping (Algorithm 1): QKV for the
                         active set, scatter partial KV update, attention
                         against full cached KV (Pallas kernel), FFN,
                         importance score I = α·conf + (1−α)·varnorm at the
                         skip layers, argsort-top-k selection, partial
                         indicator-cache update.  skip=[] gives the
                         DualCache baseline step.
  * ``observe``        — full forward that additionally returns hidden and
                         Q/K/V states at probe layers (Figures 1/2/5–8).

Cache-interchange convention (performance-critical, see DESIGN.md):
caches cross the Rust↔executable boundary in **bf16** and are upcast to
f32 in-graph; the step returns only the *block slice* of the updated KV so
per-iteration downloads stay small.  All shapes are static; top-k is
argsort-based because xla_extension 0.5.1 cannot parse the `topk` HLO op.
"""

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .modelcfg import ModelCfg, param_specs
from .kernels.attention import attention
from .kernels.varnorm import varnorm
from .kernels.ref import attention_ref, varnorm_ref

CACHE_DT = jnp.bfloat16

INDICATORS = ("h", "q", "k", "v")


class Layer(NamedTuple):
    attn_norm: jax.Array
    wq: jax.Array
    wk: jax.Array
    wv: jax.Array
    wo: jax.Array
    ffn_norm: jax.Array
    w_gate: jax.Array
    w_up: jax.Array
    w_down: jax.Array


class Params(NamedTuple):
    embed: jax.Array
    layers: tuple  # tuple[Layer]
    out_norm: jax.Array
    head: jax.Array


def params_from_flat(cfg: ModelCfg, flat):
    """Rebuild the Params pytree from the canonical flat ordering
    (see modelcfg.param_specs)."""
    assert len(flat) == len(param_specs(cfg))
    it = iter(flat)
    embed = next(it)
    layers = []
    for _ in range(cfg.n_layers):
        layers.append(Layer(*(next(it) for _ in range(9))))
    out_norm = next(it)
    head = next(it)
    return Params(embed, tuple(layers), out_norm, head)


def params_to_flat(p: Params):
    flat = [p.embed]
    for l in p.layers:
        flat.extend(l)
    flat += [p.out_norm, p.head]
    return flat


def init_params(cfg: ModelCfg, key):
    flat = []
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("norm"):
            flat.append(jnp.ones(shape, jnp.float32))
        else:
            fan_in = shape[0] if len(shape) == 2 else shape[-1]
            std = fan_in**-0.5
            flat.append(std * jax.random.normal(sub, shape, jnp.float32))
    return params_from_flat(cfg, flat)


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------


def rmsnorm(x, g, eps=1e-6):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * g


def rope(x, pos, base):
    """x: [B, S, H, hd]; pos: [B, S] int32 absolute positions."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = base ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos[..., None].astype(jnp.float32) * freqs      # [B, S, half]
    cos = jnp.cos(ang)[:, :, None, :]                     # [B, S, 1, half]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)


def swiglu(x, l: Layer):
    return (jax.nn.silu(x @ l.w_gate) * (x @ l.w_up)) @ l.w_down


def _qkv(cfg: ModelCfg, l: Layer, xn, pos):
    """Project + RoPE. Returns q [B,S,Hq,hd], k/v [B,S,Hkv,hd]."""
    b, s, _ = xn.shape
    hd = cfg.head_dim
    q = (xn @ l.wq).reshape(b, s, cfg.n_heads, hd)
    k = (xn @ l.wk).reshape(b, s, cfg.n_kv_heads, hd)
    v = (xn @ l.wv).reshape(b, s, cfg.n_kv_heads, hd)
    q = rope(q, pos, cfg.rope_base)
    k = rope(k, pos, cfg.rope_base)
    return q, k, v


def argsort_topk(scores, k):
    """Top-k indices by score, descending, stable. argsort-based: lowers
    to an HLO `sort`, which xla_extension 0.5.1 parses ( `topk` is not)."""
    order = jnp.argsort(-scores, axis=-1, stable=True)
    return order[..., :k]


def _scatter_rows(cache, idx, rows):
    """cache [B, N, ...], idx [B, S], rows [B, S, ...] -> per-batch scatter."""
    return jax.vmap(lambda c, i, r: c.at[i].set(r))(cache, idx, rows)


def _gather_rows(cache, idx):
    return jax.vmap(lambda c, i: c[i])(cache, idx)


# ---------------------------------------------------------------------------
# prefill / vanilla forward
# ---------------------------------------------------------------------------


def prefill(cfg: ModelCfg, params: Params, tokens, *, skip_layers=None,
            use_pallas=True, kv_tile=64, logits_gen=False):
    """Full forward over [B, ctx] tokens.

    Serves as cache initialization, the *vanilla* per-iteration step, and
    every refresh pass (prompt and block refreshes recompute the full
    sequence — see DESIGN.md §4).

    Returns (logits, kv_cache, ind_caches, attn_mass):
      logits     f32 [B, ctx, V] — or the gen-region slice [B, gen, V]
                 when ``logits_gen``: the serving runtime only ever reads
                 the gen rows, so slicing in-graph keeps the prompt-region
                 rows off the bus (the same 60% downlink cut the
                 device-apply prefill already ships; the Host-fallback
                 ``vanilla_b*`` / ``prefill_b*`` executables opt in via
                 this flag)
      kv_cache   bf16 [L, 2, B, Hkv, ctx, hd]
      ind_caches dict ind -> bf16 [n_layers', B, gen, d]  (gen region only;
                 all layers by default so any skip config can slice)
      attn_mass  f32 [B, ctx] — mean last-layer attention mass received by
                 each position from gen-region queries (sparse selection).
    """
    if skip_layers is None:
        skip_layers = list(range(cfg.n_layers))
    b, ctx = tokens.shape
    gen0 = cfg.prompt_len
    attn = attention if use_pallas else attention_ref

    x = params.embed[tokens]
    pos = jnp.broadcast_to(jnp.arange(ctx, dtype=jnp.int32)[None], (b, ctx))
    kv_all = []
    ind = {i: [] for i in INDICATORS}
    attn_mass = None
    for li, l in enumerate(cfg_layers(cfg, params)):
        xn = rmsnorm(x, l.attn_norm)
        q, k, v = _qkv(cfg, l, xn, pos)
        qh = q.transpose(0, 2, 1, 3)
        kh = k.transpose(0, 2, 1, 3)
        vh = v.transpose(0, 2, 1, 3)
        kv_all.append(jnp.stack([kh, vh]))        # [2, B, Hkv, ctx, hd]
        if use_pallas:
            o = attn(qh, kh, vh, kv_tile=kv_tile)
        else:
            o = attn(qh, kh, vh)
        if li == cfg.n_layers - 1:
            # attention mass for sparse-KV selection: probs of gen-region
            # queries over all positions, averaged (ref path: cheap, once).
            p = _attn_probs(cfg, qh[:, :, gen0:], kh)
            attn_mass = jnp.mean(p, axis=(1, 2))  # [B, ctx]
        o = o.transpose(0, 2, 1, 3).reshape(b, ctx, cfg.d_model)
        x = x + o @ l.wo
        h = x + swiglu(rmsnorm(x, l.ffn_norm), l)
        if li in skip_layers:
            ind["h"].append(h[:, gen0:])
            ind["q"].append(q.reshape(b, ctx, -1)[:, gen0:])
            ind["k"].append(_expand_kv(cfg, k).reshape(b, ctx, -1)[:, gen0:])
            ind["v"].append(_expand_kv(cfg, v).reshape(b, ctx, -1)[:, gen0:])
        x = h
    logits = rmsnorm(x, params.out_norm) @ params.head
    if logits_gen:
        logits = logits[:, gen0:]
    kv_cache = jnp.stack(kv_all).astype(CACHE_DT)
    ind_caches = {
        key: jnp.stack(vals).astype(CACHE_DT) for key, vals in ind.items()
    }
    return logits, kv_cache, ind_caches, attn_mass


def prefill_apply(cfg: ModelCfg, params: Params, tokens, kv_prev, ind_prev,
                  conf_prev, refresh, *, indicator="h", use_pallas=True,
                  kv_tile=64):
    """Device-apply prefill: run the full forward and merge its outputs
    into the resident cache tensors in-graph, refreshing only the rows
    where ``refresh`` (i32 [B] 0/1) is set — the row-filtered merge that
    grounds a newly admitted slot without perturbing co-resident
    occupants, executed on device so nothing is downloaded and re-shipped.

    Confidence is computed in-graph from the gen-region logits (max
    softmax probability), replacing the host conf round-trip.

    Returns (logits_gen f32 [B, gen, V]  — the gen-region slice only,
             kv_new bf16 [L, 2, B, Hkv, ctx, hd],
             ind_new bf16 [L, B, gen, d]  (the ``indicator`` cache),
             conf_new f32 [B, gen]).
    The kv/ind/conf outputs are device-retained and chained back into the
    next prefill_apply / step-apply call, so the only download is the
    logit output — and the host sampler and confidence mirror read
    gen-region rows exclusively, so the prompt-region logits are sliced
    off in-graph rather than shipped (B × prompt_len × V floats per
    grounding prefill, 60% of the old [B, ctx, V] downlink at nano
    scale). No attn_mass output: the only consumer is the host-side
    sparse rebuild, and sparse configs run the stateless Host-apply path.
    """
    gen_logits, kv, ind, _attn_mass = prefill(
        cfg, params, tokens, use_pallas=use_pallas, kv_tile=kv_tile,
        logits_gen=True)                                      # [B, gen, V]
    r = refresh.astype(jnp.bool_)                             # [B]
    kv_new = jnp.where(r[None, None, :, None, None, None], kv, kv_prev)
    ind_new = jnp.where(r[None, :, None, None], ind[indicator], ind_prev)
    conf_full = jax.nn.softmax(gen_logits, axis=-1).max(-1)   # [B, gen]
    conf_new = jnp.where(r[:, None], conf_full, conf_prev)
    return gen_logits, kv_new, ind_new, conf_new


def prefill_apply_blk(cfg: ModelCfg, params: Params, tokens, kv_prev,
                      ind_prev, conf_prev, refresh, blk_start, *, block,
                      indicator="h", use_pallas=True, kv_tile=64):
    """Block-sliced device-apply prefill: identical cache/conf merge to
    [`prefill_apply`], but the logit downlink is each slot's CURRENT
    block window only — ``blk_start`` (i32 [B], gen-relative block start
    per slot) gathers ``[B, block, V]`` rows in-graph instead of
    shipping the whole gen region. The host sampler only ever reads the
    refreshed slot's current block, so a grounding prefill pays
    block/gen of the old logit downlink (4–8× at nano scale). Vacant
    rows' blk_start are don't-cares (clamped in-graph).

    Returns (logits_blk f32 [B, block, V], kv_new, ind_new, conf_new) —
    the cache outputs are device-retained and chained exactly like
    [`prefill_apply`]'s.
    """
    gen_logits, kv_new, ind_new, conf_new = prefill_apply(
        cfg, params, tokens, kv_prev, ind_prev, conf_prev, refresh,
        indicator=indicator, use_pallas=use_pallas, kv_tile=kv_tile)
    gen_live = gen_logits.shape[1]
    base = jnp.clip(blk_start, 0, gen_live - block)           # [B]
    idx = base[:, None] + jnp.arange(block, dtype=jnp.int32)[None]
    logits_blk = _gather_rows(gen_logits, idx)                # [B, blk, V]
    return logits_blk, kv_new, ind_new, conf_new


def _expand_kv(cfg, t):
    """[B, S, Hkv, hd] -> [B, S, d] by repeating kv heads to Hq (so K/V
    indicator tensors have the same [.., d] shape as hidden/Q)."""
    group = cfg.n_heads // cfg.n_kv_heads
    return jnp.repeat(t, group, axis=2)


def _attn_probs(cfg, qh, kh):
    """softmax probs [B, Hq, S, T] (ref path, used for attention mass)."""
    group = cfg.n_heads // cfg.n_kv_heads
    kfull = jnp.repeat(kh, group, axis=1)
    s = jnp.einsum("bhsd,bhtd->bhst", qh, kfull) / (cfg.head_dim**0.5)
    return jax.nn.softmax(s, axis=-1)


def cfg_layers(cfg, params):
    return params.layers


# ---------------------------------------------------------------------------
# decode step (DualCache when skip=[], ES-dLLM otherwise — Algorithm 1)
# ---------------------------------------------------------------------------


def step(cfg: ModelCfg, params: Params, x_tok, block_start, kv_cache,
         ind_cache, conf, alpha, *, block, skip, indicator="h",
         ind_layers=None, kv_len=None, use_pallas=True, kv_tile=64,
         apply=False, occ=None):
    """One decode iteration over the current block.

    x_tok       i32 [B, block]       current block tokens (incl. masks)
    block_start i32 scalar           absolute position of the block start
    kv_cache    bf16 [L, 2, B, Hkv, T, hd]   T = kv_len (ctx, or pruned)
    ind_cache   bf16 [n_ind, B, gen, d]      indicator tensor cache
                (``apply=True``: the FULL per-name cache, n_ind = L)
    conf        f32 [B, gen_live]    confidence from previous iterations
                (``apply=False``: occupancy-masked host-side;
                ``apply=True``: raw — the mask is applied in-graph).
                The live gen length is read off this tensor's shape, so
                the same code lowers the full-context executables
                (gen_live = gen) and the suffix-pruned context tiers
                (gen_live < gen: converged trailing blocks dropped from
                the attention context, see ``kv_len`` below).
    alpha       f32 scalar           Eq. 1 mixing weight
    skip        [(layer, ratio)]     static skip spec; [] = DualCache
    ind_layers  layers whose indicator cache rows are maintained; defaults
                to the skip layers. The DualCache/refresh variant passes
                all layers (so any ES config sees fresh indicators after a
                block refresh); skip layers must be a subset.
    kv_len      cache length; when < prompt_len + gen_live the cache is
                prompt-pruned (sparse attention): retained prompt rows
                first, then the live gen region, so cache row of absolute
                gen position p is (kv_len - gen_live) + (p - prompt_len).
                A suffix-pruned context tier passes
                kv_len = prompt_len + gen_live with the full prompt
                retained — the same formula then maps gen rows 1:1.
    apply       device-apply mode: instead of returning the block slices
                for a host-side scatter, scatter the updates into the full
                cache tensors in-graph (dynamic-update-slice) and compute
                the merged confidence from the final logits, so the caller
                can retain the outputs on device and feed them back to the
                next call.  Rows where ``occ`` is 0 (vacant slots, slots
                working a different block) pass through unchanged and are
                pinned to confidence -1 for the importance selection.
    occ         i32 [B] 0/1 occupancy mask (required when ``apply``).

    Returns (``apply=False``):
             (logits_sel f32 [B, k_final, V], pos_sel i32 [B, k_final],
              kv_block bf16 [L, 2, B, Hkv, block, hd],
              ind_block bf16 [n_ind, B, block, d])
            (``apply=True``):
             (logits_sel, pos_sel,
              kv_new bf16 [L, 2, B, Hkv, T, hd],
              ind_new bf16 [L, B, gen, d],
              conf_new f32 [B, gen]).
    """
    b = x_tok.shape[0]
    gen0 = cfg.prompt_len
    # live gen length: the gen-region state arrays (conf, ind) are sized
    # to the live context tier, not the compiled maximum — everything
    # downstream indexes gen rows relative to gen0, so shrinking the
    # arrays is all a tier variant needs
    gen_live = conf.shape[1]
    kv_len = kv_len or (gen0 + gen_live if gen_live < cfg.gen_len
                        else cfg.ctx)
    skip_map = dict(skip)
    if ind_layers is None:
        ind_layers = sorted(skip_map)
    assert all(l in ind_layers for l in skip_map), (skip_map, ind_layers)
    if apply:
        assert occ is not None, "apply mode needs the occupancy mask"
        assert ind_cache.shape[0] == cfg.n_layers, ind_cache.shape
        occ_row = occ.astype(jnp.bool_)                  # [B]
    else:
        assert len(ind_layers) == ind_cache.shape[0] or not ind_layers
    attn = attention if use_pallas else attention_ref
    vnorm = varnorm if use_pallas else varnorm_ref

    # cache row offset of the block inside the (possibly pruned) KV
    # cache: prompt-pruned sparse rows and suffix-pruned tiers both
    # reduce to "non-gen rows first, then the live gen region"
    cache_off = (kv_len - gen_live) - gen0 + block_start

    x = params.embed[x_tok]                                  # [B, blk, d]
    pos = block_start + jnp.arange(block, dtype=jnp.int32)
    pos = jnp.broadcast_to(pos[None], (b, block))            # absolute
    # index of each active row within the block (for slice-free scatters)
    rel = jnp.broadcast_to(jnp.arange(block, dtype=jnp.int32)[None],
                           (b, block))

    # Performance note: the cache tensors are treated as read-only; per
    # layer we materialize only that layer's updated K/V (one layer-sized
    # scatter) and collect the *block slices* for the outputs. Functional
    # whole-cache updates (kv.at[li].set) would make XLA copy the full
    # multi-MB cache once per layer per iteration.  In apply mode the
    # output IS the updated full cache, so the per-layer updates are
    # collected whole (with non-occupant rows passed through) instead.
    kv_blocks = []   # per layer: [2, B, Hkv, block, hd] (or full in apply)
    ind_blocks = []  # per ind layer: [B, block, d] (or [B, gen, d])
    ind_by_layer = {}
    si = 0
    for li, l in enumerate(params.layers):
        s_act = x.shape[1]
        xn = rmsnorm(x, l.attn_norm)
        q, k, v = _qkv(cfg, l, xn, pos)
        kh = k.transpose(0, 2, 1, 3)                         # [B,Hkv,s,hd]
        vh = v.transpose(0, 2, 1, 3)

        # partial KV update: scatter active rows into this layer's K/V
        cache_idx = cache_off + rel
        k_cache = kv_cache[li, 0].astype(jnp.float32)        # [B,Hkv,T,hd]
        v_cache = kv_cache[li, 1].astype(jnp.float32)
        k_l = _scatter_rows(k_cache.transpose(0, 2, 1, 3), cache_idx,
                            kh.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
        v_l = _scatter_rows(v_cache.transpose(0, 2, 1, 3), cache_idx,
                            vh.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
        if apply:
            # device-apply: keep the whole updated layer cache, with
            # non-occupant rows passed through untouched (their computed
            # values are garbage by the row-filtered-merge contract)
            o4 = occ_row[:, None, None, None]
            kv_blocks.append(jnp.stack([
                jnp.where(o4, k_l, k_cache),
                jnp.where(o4, v_l, v_cache),
            ]))
        else:
            kv_blocks.append(jnp.stack([
                jax.lax.dynamic_slice_in_dim(k_l, cache_off, block, axis=2),
                jax.lax.dynamic_slice_in_dim(v_l, cache_off, block, axis=2),
            ]))

        qh = q.transpose(0, 2, 1, 3)
        if use_pallas:
            o = attn(qh, k_l, v_l, kv_tile=min(kv_tile, kv_len))
        else:
            o = attn(qh, k_l, v_l)
        o = o.transpose(0, 2, 1, 3).reshape(b, s_act, cfg.d_model)
        x = x + o @ l.wo
        h = x + swiglu(rmsnorm(x, l.ffn_norm), l)

        if li in ind_layers:
            # indicator tensor for this layer
            if indicator == "h":
                t_now = h
            elif indicator == "q":
                t_now = q.reshape(b, s_act, -1)
            elif indicator == "k":
                t_now = _expand_kv(cfg, k).reshape(b, s_act, -1)
            else:
                t_now = _expand_kv(cfg, v).reshape(b, s_act, -1)

            gen_idx = pos - gen0                              # rows in gen
            cache_row = li if apply else si
            ind_l = ind_cache[cache_row].astype(jnp.float32)  # [B,gen,d]
            t_prev = _gather_rows(ind_l, gen_idx)

            if apply:
                # partial indicator-cache update applied to the full
                # cache row in-graph; non-occupant rows pass through
                upd = _scatter_rows(ind_l, gen_idx, t_now)
                ind_by_layer[li] = jnp.where(occ_row[:, None, None],
                                             upd, ind_l)
            else:
                # partial indicator-cache update for ALL active rows
                # (line 8), materialized as the block slice only
                blk_prev = jax.lax.dynamic_slice_in_dim(
                    ind_l, block_start - gen0, block, axis=1)
                ind_blocks.append(_scatter_rows(blk_prev, rel, t_now))

            if li in skip_map:
                var = vnorm(t_now, t_prev)                    # [B, s_act]
                c_prev = _gather_rows(conf[:, :, None], gen_idx)[..., 0]
                if apply:
                    # the occupancy mask lands in-graph: vacant rows are
                    # pinned below any real confidence so they never win
                    # the importance selection (host-side masking gone)
                    c_prev = jnp.where(occ_row[:, None], c_prev, -1.0)
                imp = alpha * c_prev + (1.0 - alpha) * var    # Eq. 1

                # early skip: keep top-(1-r)|S| rows (lines 13–14)
                k_keep = max(1, int(round(s_act * (1.0 - skip_map[li]))))
                sel = argsort_topk(imp, k_keep)               # [B, k_keep]
                h = _gather_rows(h, sel)
                pos = jnp.take_along_axis(pos, sel, axis=1)
                rel = jnp.take_along_axis(rel, sel, axis=1)
            si += 1
        x = h

    logits = rmsnorm(x, params.out_norm) @ params.head        # [B,k_f,V]

    if apply:
        # device-apply outputs: full updated caches + in-graph merged
        # confidence, retainable on device and chained into the next call
        kv_new = jnp.stack(kv_blocks)            # [L,2,B,Hkv,T,hd]
        ind_new = jnp.stack([
            ind_by_layer.get(li, ind_cache[li].astype(jnp.float32))
            for li in range(cfg.n_layers)
        ])                                       # [L,B,gen,d]
        # confidence = max softmax probability of the surviving
        # positions' logits, scattered into the confidence state (the
        # same update the host mirror applies from the downloaded rows)
        prob = jax.nn.softmax(logits, axis=-1).max(-1)        # [B,k_f]
        gen_idx = pos - gen0
        conf_upd = _scatter_rows(conf[:, :, None], gen_idx,
                                 prob[:, :, None])[..., 0]
        conf_new = jnp.where(occ_row[:, None], conf_upd, conf)
        return (logits, pos.astype(jnp.int32), kv_new.astype(CACHE_DT),
                ind_new.astype(CACHE_DT), conf_new)

    # outputs: block slices only (keeps the per-iteration download small)
    kv_block = jnp.stack(kv_blocks)              # [L,2,B,Hkv,block,hd]
    if ind_blocks:
        ind_block = jnp.stack(ind_blocks)        # [n_ind,B,block,d]
    else:
        ind_block = jnp.zeros((1, b, block, cfg.d_model), jnp.float32)
    return (logits, pos.astype(jnp.int32),
            kv_block.astype(CACHE_DT), ind_block.astype(CACHE_DT))


def _commit_unmask(x_tok, logits, pos, block_start, conf_blk, tok_hat,
                   tok_noeos, occ_row, threshold, mask_id, eos_id):
    """One in-graph unmask decision over the FULL block window,
    replicating the host sampler's rule exactly: commit the
    highest-confidence masked position — confidence read from the
    chained state ``conf_blk``, the same values the host conf mirror
    holds, with the LAST maximum winning ties like Rust's ``max_by`` —
    plus every masked position whose confidence clears ``threshold``
    (``threshold > 1`` disables parallel commits — low-confidence
    greedy). Token choice replays the host rule too: argmax with the
    mask id banned, and EOS banned while non-EOS content exists to the
    position's right (the §B.2 EOS guard; under blockwise decode every
    later block is still fully masked, so the block window sees all the
    content the host's gen-region scan would). ``tok_hat`` /
    ``tok_noeos`` are chained per-position argmax caches ([B, block]
    i32, seeded from the host logits mirror and refreshed here at this
    iteration's surviving rows), so a position the skip chain dropped
    this iteration still commits the token the host mirror would have
    sampled from its stale logits row. Returns ``(x_tok_new, tok_hat,
    tok_noeos, n_committed i32 [B], greedy_rel i32 [B], greedy_tok i32
    [B])``; vacant rows commit nothing and their greedy pos/tok are
    don't-cares."""
    _, blk = x_tok.shape
    rel = (pos - block_start).astype(jnp.int32)               # [B, kf]
    # refresh the argmax caches at the surviving rows (occupancy-gated:
    # spectator rows' logits are garbage by the row-filter contract)
    banned = logits.at[:, :, mask_id].set(-jnp.inf)
    hat = jnp.argmax(banned, axis=-1).astype(jnp.int32)       # [B, kf]
    noeos = jnp.argmax(banned.at[:, :, eos_id].set(-jnp.inf),
                       axis=-1).astype(jnp.int32)             # [B, kf]
    o2 = occ_row[:, None]
    tok_hat = jnp.where(
        o2, _scatter_rows(tok_hat[:, :, None], rel,
                          hat[:, :, None])[..., 0], tok_hat)
    tok_noeos = jnp.where(
        o2, _scatter_rows(tok_noeos[:, :, None], rel,
                          noeos[:, :, None])[..., 0], tok_noeos)
    # selection over the whole block from the chained confidence —
    # decide_unmask's rule; reversed argmax picks the LAST maximum
    is_masked = (x_tok == mask_id) & o2                       # [B, blk]
    cand = jnp.where(is_masked, conf_blk, -jnp.inf)
    best = blk - 1 - jnp.argmax(cand[:, ::-1], axis=1)        # [B]
    # EOS guard: strictly-right content within the block window
    content = (x_tok != mask_id) & (x_tok != eos_id)
    right = jnp.cumsum(content[:, ::-1].astype(jnp.int32), axis=1)[:, ::-1]
    has_after = (right - content.astype(jnp.int32)) > 0       # [B, blk]
    choice = jnp.where(has_after, tok_noeos, tok_hat)         # [B, blk]
    commit = is_masked & ((jnp.arange(blk)[None] == best[:, None])
                          | (conf_blk > threshold))
    x_new = jnp.where(commit, choice, x_tok)
    greedy_tok = jnp.take_along_axis(choice, best[:, None], axis=1)[:, 0]
    return (x_new, tok_hat, tok_noeos,
            commit.sum(axis=1).astype(jnp.int32),
            best.astype(jnp.int32), greedy_tok)


def step_k(cfg: ModelCfg, params: Params, x_tok, block_start, kv_cache,
           ind_cache, conf, occ, alpha, threshold, tok_seed, *, k, block,
           skip, mask_id, eos_id, indicator="h", ind_layers=None,
           use_pallas=True, kv_tile=64):
    """`k` diffusion iterations unrolled in-graph: each inner iteration
    runs `step(apply=True)` over the chained kv/ind/conf state, then
    commits tokens with [`_commit_unmask`] — the host sampler's greedy
    rule (highest-confidence masked block position by the chained
    confidence, mask banned, EOS guarded) plus any position clearing
    `threshold` — and feeds the advanced block tokens straight into the
    next iteration. The host round-trip is paid once for the whole run.
    Uplink: token rows, the occupancy mask, and ``tok_seed`` ([2, B,
    block] i32 — the host logits mirror's per-position argmax with the
    mask banned, and with mask+EOS banned), which seeds the argmax
    caches so positions that never survive an inner iteration's skip
    still commit what the host would have. Downlink: the **final**
    iteration's selected logit rows + positions, the per-iteration
    greedy commits ``commit_pos`` / ``commit_tok`` ([B, k] i32,
    block-relative; the host applies these directly — it never replays
    decisions from the final iteration's logits, which would diverge
    from the per-iteration logits the in-graph commits actually used),
    and a per-slot committed-token count auditing that each inner
    iteration committed exactly one token. Scheduling contract: the
    caller must guarantee the block cannot complete before the final
    inner iteration (the Rust scheduler caps k at the masked count) and
    that every slot decodes greedily with the EOS guard on, so fused
    runs are trajectory-exact against k single steps."""
    occ_row = occ.astype(jnp.bool_)
    gen0 = cfg.prompt_len
    tok_hat = tok_seed[0]
    tok_noeos = tok_seed[1]
    committed = jnp.zeros((x_tok.shape[0],), jnp.int32)
    commit_pos, commit_tok = [], []
    logits = pos = None
    for _ in range(k):
        logits, pos, kv_cache, ind_cache, conf = step(
            cfg, params, x_tok, block_start, kv_cache, ind_cache, conf,
            alpha, block=block, skip=skip, indicator=indicator,
            ind_layers=ind_layers, kv_len=kv_cache.shape[4],
            use_pallas=use_pallas, kv_tile=kv_tile, apply=True, occ=occ)
        conf_blk = jax.lax.dynamic_slice_in_dim(
            conf, block_start - gen0, block, axis=1)
        x_tok, tok_hat, tok_noeos, n, g_rel, g_tok = _commit_unmask(
            x_tok, logits, pos, block_start, conf_blk, tok_hat,
            tok_noeos, occ_row, threshold, mask_id, eos_id)
        committed = committed + n
        commit_pos.append(g_rel)
        commit_tok.append(g_tok)
    return (logits, pos, kv_cache, ind_cache, conf, committed,
            jnp.stack(commit_pos, axis=1), jnp.stack(commit_tok, axis=1))


# ---------------------------------------------------------------------------
# observation forward (Figures 1, 2, 5–8): full forward + probe tensors
# ---------------------------------------------------------------------------


def observe(cfg: ModelCfg, params: Params, tokens, *, probe_layers,
            use_pallas=True):
    """Full forward returning logits + per-probe-layer hidden/Q/K/V of the
    gen region (f32 — these go to the analysis pipeline, not the cache)."""
    b, ctx = tokens.shape
    gen0 = cfg.prompt_len
    attn = attention if use_pallas else attention_ref

    x = params.embed[tokens]
    pos = jnp.broadcast_to(jnp.arange(ctx, dtype=jnp.int32)[None], (b, ctx))
    probes = []
    for li, l in enumerate(params.layers):
        xn = rmsnorm(x, l.attn_norm)
        q, k, v = _qkv(cfg, l, xn, pos)
        qh, kh, vh = (t.transpose(0, 2, 1, 3) for t in (q, k, v))
        o = attn(qh, kh, vh)
        o = o.transpose(0, 2, 1, 3).reshape(b, ctx, cfg.d_model)
        x = x + o @ l.wo
        h = x + swiglu(rmsnorm(x, l.ffn_norm), l)
        if li in probe_layers:
            probes.append(jnp.stack([
                h[:, gen0:],
                q.reshape(b, ctx, -1)[:, gen0:],
                _expand_kv(cfg, k).reshape(b, ctx, -1)[:, gen0:],
                _expand_kv(cfg, v).reshape(b, ctx, -1)[:, gen0:],
            ]))                                   # [4, B, gen, d]
        x = h
    logits = rmsnorm(x, params.out_norm) @ params.head
    return logits, jnp.stack(probes)              # [n_probe, 4, B, gen, d]


# ---------------------------------------------------------------------------
# training forward (differentiable; ref kernels)
# ---------------------------------------------------------------------------


def train_logits(cfg: ModelCfg, params: Params, tokens):
    """Differentiable full forward -> logits [B, ctx, V] (ref attention —
    the Pallas interpret kernel has no registered VJP)."""
    b, ctx = tokens.shape
    x = params.embed[tokens]
    pos = jnp.broadcast_to(jnp.arange(ctx, dtype=jnp.int32)[None], (b, ctx))
    for l in params.layers:
        xn = rmsnorm(x, l.attn_norm)
        q, k, v = _qkv(cfg, l, xn, pos)
        qh, kh, vh = (t.transpose(0, 2, 1, 3) for t in (q, k, v))
        o = attention_ref(qh, kh, vh)
        o = o.transpose(0, 2, 1, 3).reshape(b, ctx, cfg.d_model)
        x = x + o @ l.wo
        x = x + swiglu(rmsnorm(x, l.ffn_norm), l)
    return rmsnorm(x, params.out_norm) @ params.head
