//! Char-level tokenizer mirroring `python/compile/tasks.py`.
//!
//! The table is loaded from `artifacts/vocab.json` (the build-time source
//! of truth) so Rust and the trained model can never disagree.

use crate::json::Json;
use anyhow::{anyhow, Context, Result};

#[derive(Debug, Clone)]
pub struct Tokenizer {
    tokens: Vec<String>,
    stoi: std::collections::HashMap<char, i32>,
    pub vocab_size: usize,
    pub pad: i32,
    pub mask: i32,
    pub eos: i32,
    pub bos: i32,
}

impl Tokenizer {
    pub fn from_json(j: &Json) -> Result<Tokenizer> {
        let tokens: Vec<String> = j
            .get("tokens")
            .as_arr()
            .ok_or_else(|| anyhow!("vocab.json: missing tokens"))?
            .iter()
            .map(|t| t.as_str().unwrap_or("").to_string())
            .collect();
        let specials = 4;
        let mut stoi = std::collections::HashMap::new();
        for (i, t) in tokens.iter().enumerate().skip(specials) {
            let mut chars = t.chars();
            let c = chars.next().ok_or_else(|| anyhow!("empty token"))?;
            if chars.next().is_some() {
                return Err(anyhow!("multi-char token {t:?}"));
            }
            stoi.insert(c, i as i32);
        }
        Ok(Tokenizer {
            stoi,
            vocab_size: j.get("vocab_size").as_usize().unwrap_or(tokens.len()),
            pad: j.get("pad").as_i64().unwrap_or(0) as i32,
            mask: j.get("mask").as_i64().unwrap_or(1) as i32,
            eos: j.get("eos").as_i64().unwrap_or(2) as i32,
            bos: j.get("bos").as_i64().unwrap_or(3) as i32,
            tokens,
        })
    }

    pub fn load(path: &std::path::Path) -> Result<Tokenizer> {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_json(&Json::parse(&src).map_err(|e| anyhow!("{e}"))?)
    }

    pub fn encode(&self, s: &str) -> Result<Vec<i32>> {
        s.chars()
            .map(|c| self.stoi.get(&c).copied().ok_or_else(|| anyhow!("unknown char {c:?}")))
            .collect()
    }

    /// Decode, stopping at the first EOS and skipping specials.
    pub fn decode(&self, ids: &[i32]) -> String {
        let mut out = String::new();
        for &i in ids {
            if i == self.eos {
                break;
            }
            if i >= 4 && (i as usize) < self.tokens.len() {
                out.push_str(&self.tokens[i as usize]);
            }
        }
        out
    }

    /// The build-time vocabulary table, constructed without artifacts:
    /// 4 specials then `0-9`, `a-z` and the task punctuation, exactly as
    /// `python/compile/tasks.py` emits it into `artifacts/vocab.json`.
    /// Used by the simulation backend and tests; the integration suite
    /// verifies the real `vocab.json` agrees.
    pub fn builtin() -> Tokenizer {
        let mut tokens: Vec<String> =
            vec!["<pad>".into(), "<mask>".into(), "<eos>".into(), "<bos>".into()];
        for c in ('0'..='9').chain('a'..='z').chain("+-*/=()[],.:?><|&! ".chars()) {
            tokens.push(c.to_string());
        }
        let arr = Json::Arr(tokens.into_iter().map(Json::Str).collect());
        let j = crate::json::obj(vec![
            ("tokens", arr),
            ("vocab_size", Json::Num(64.0)),
            ("pad", Json::Num(0.0)),
            ("mask", Json::Num(1.0)),
            ("eos", Json::Num(2.0)),
            ("bos", Json::Num(3.0)),
        ]);
        Self::from_json(&j).expect("builtin vocabulary is well-formed")
    }

    /// Prompt right-padded with PAD to `prompt_len` (build-time layout).
    pub fn encode_prompt(&self, s: &str, prompt_len: usize) -> Result<Vec<i32>> {
        let mut ids = self.encode(s)?;
        if ids.len() > prompt_len {
            return Err(anyhow!("prompt too long: {} > {prompt_len}", ids.len()));
        }
        ids.resize(prompt_len, self.pad);
        Ok(ids)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok() -> Tokenizer {
        // the in-crate copy of the build-time table (kept in sync by the
        // integration test that loads the real artifacts/vocab.json)
        Tokenizer::builtin()
    }

    #[test]
    fn roundtrip() {
        let t = tok();
        let ids = t.encode("sort(3,1)=1,3").unwrap();
        assert_eq!(t.decode(&ids), "sort(3,1)=1,3");
    }

    #[test]
    fn decode_stops_at_eos() {
        let t = tok();
        let mut ids = t.encode("42").unwrap();
        ids.push(t.eos);
        ids.extend(t.encode("junk").unwrap());
        assert_eq!(t.decode(&ids), "42");
    }

    #[test]
    fn prompt_padding() {
        let t = tok();
        let ids = t.encode_prompt("1+1=", 10).unwrap();
        assert_eq!(ids.len(), 10);
        assert_eq!(&ids[4..], &[t.pad; 6]);
        assert!(t.encode_prompt("123456789012", 4).is_err());
    }

    #[test]
    fn unknown_char_rejected() {
        let t = tok();
        assert!(t.encode("Ü").is_err());
    }
}
