//! Remasking / unmasking policies (paper §2, §B.2 and Fast-dLLM's
//! confidence-aware parallel decoding).
//!
//! Two families mirror the paper's subjects:
//!   * `LowConfidence` — LLaDA's low-confidence remasking: unmask the
//!     single highest-confidence masked position per iteration.
//!   * `MaskgitPlus`   — Dream's maskgit-plus: same position selection,
//!     token drawn with top-k/top-p/temperature sampling.
//!
//! Parallel decoding additionally unmasks *every* masked position whose
//! confidence exceeds a threshold (≥1 position per iteration).
//! The EOS guard (paper §B.2) suppresses EOS at a position while the last
//! gen position is still masked.

use crate::rng::SplitMix;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Strategy {
    /// LLaDA: argmax token at the highest-confidence masked position
    LowConfidence,
    /// Dream: top-k/top-p sampled token (equals argmax at temperature 0)
    MaskgitPlus { top_k: usize, top_p: f32 },
}

#[derive(Debug, Clone, Copy)]
pub struct SamplerCfg {
    pub strategy: Strategy,
    pub temperature: f32,
    /// confidence-aware parallel decoding threshold (None = one token/iter)
    pub parallel_threshold: Option<f32>,
    /// suppress EOS while the final gen position is masked (paper §B.2)
    pub eos_guard: bool,
}

impl SamplerCfg {
    pub fn llada() -> SamplerCfg {
        SamplerCfg {
            strategy: Strategy::LowConfidence,
            temperature: 0.0,
            parallel_threshold: None,
            eos_guard: true,
        }
    }

    pub fn dream() -> SamplerCfg {
        SamplerCfg {
            // vocab is 64; the paper's k=50 top-k maps to 20 here
            strategy: Strategy::MaskgitPlus { top_k: 20, top_p: 0.95 },
            temperature: 0.0,
            parallel_threshold: None,
            eos_guard: true,
        }
    }

    pub fn with_parallel(mut self, threshold: f32) -> SamplerCfg {
        self.parallel_threshold = Some(threshold);
        self
    }
}

/// One sequence's view for an unmask decision over the current block.
pub struct UnmaskInput<'a> {
    /// latest logits rows for gen positions [gen, V]
    pub logits: &'a [f32],
    /// latest confidence per gen position [gen]
    pub conf: &'a [f32],
    /// current gen-region tokens [gen] (mask id where still masked)
    pub gen_tokens: &'a [i32],
    /// block bounds within the gen region
    pub block_lo: usize,
    pub block_hi: usize,
    pub vocab: usize,
    pub mask_id: i32,
    pub eos_id: i32,
}

/// Positions (gen-region indices) + tokens chosen to unmask this iteration.
#[derive(Debug, Clone, Default)]
pub struct UnmaskDecision {
    pub positions: Vec<usize>,
    pub tokens: Vec<i32>,
}

/// Reusable sampling workspace. Token sampling historically cloned the
/// vocab-sized logits row (plus an ordering vector and a probability
/// vector in the maskgit path) for every sampled token; threading one
/// scratch through the decode loop keeps those allocations alive across
/// iterations instead.
#[derive(Debug, Clone, Default)]
pub struct SamplerScratch {
    row: Vec<f32>,
    order: Vec<usize>,
    probs: Vec<f32>,
}

/// Allocating convenience wrapper around [`decide_unmask_with`] (tests
/// and one-shot callers); hot loops should hold a [`SamplerScratch`].
pub fn decide_unmask(
    cfg: &SamplerCfg,
    inp: &UnmaskInput,
    rng: &mut SplitMix,
) -> UnmaskDecision {
    let mut scratch = SamplerScratch::default();
    decide_unmask_with(cfg, inp, rng, &mut scratch)
}

pub fn decide_unmask_with(
    cfg: &SamplerCfg,
    inp: &UnmaskInput,
    rng: &mut SplitMix,
    scratch: &mut SamplerScratch,
) -> UnmaskDecision {
    let masked: Vec<usize> = (inp.block_lo..inp.block_hi)
        .filter(|&g| inp.gen_tokens[g] == inp.mask_id)
        .collect();
    if masked.is_empty() {
        return UnmaskDecision::default();
    }
    let best = masked
        .iter()
        .cloned()
        .max_by(|&a, &b| inp.conf[a].partial_cmp(&inp.conf[b]).unwrap())
        .unwrap();

    let mut positions = vec![best];
    if let Some(th) = cfg.parallel_threshold {
        for &g in &masked {
            if g != best && inp.conf[g] > th {
                positions.push(g);
            }
        }
        positions.sort();
    }

    // EOS guard (§B.2): an EOS at position g would truncate any content to
    // its right, so suppress EOS while a *later* position already holds a
    // non-EOS token (with EOS-fill training the tail legitimately wants
    // EOS, so a blanket "last token masked" rule would corrupt it).
    let non_eos_after = |g: usize| {
        inp.gen_tokens[g + 1..]
            .iter()
            .any(|&t| t != inp.mask_id && t != inp.eos_id)
    };

    let mut tokens = Vec::with_capacity(positions.len());
    for &g in &positions {
        let row = &inp.logits[g * inp.vocab..(g + 1) * inp.vocab];
        tokens.push(sample_token_with(
            cfg,
            row,
            rng,
            (cfg.eos_guard && non_eos_after(g)).then_some(inp.eos_id),
            inp.mask_id,
            scratch,
        ));
    }
    UnmaskDecision { positions, tokens }
}

/// Allocating convenience wrapper around [`sample_token_with`].
pub fn sample_token(
    cfg: &SamplerCfg,
    logits: &[f32],
    rng: &mut SplitMix,
    suppress: Option<i32>,
    mask_id: i32,
) -> i32 {
    let mut scratch = SamplerScratch::default();
    sample_token_with(cfg, logits, rng, suppress, mask_id, &mut scratch)
}

/// Sample a token from a logits row, excluding `suppress` (EOS guard) and
/// the mask id (never emit the mask token). All working vectors come from
/// `scratch`, so a decode loop allocates nothing per sampled token.
pub fn sample_token_with(
    cfg: &SamplerCfg,
    logits: &[f32],
    rng: &mut SplitMix,
    suppress: Option<i32>,
    mask_id: i32,
    scratch: &mut SamplerScratch,
) -> i32 {
    let SamplerScratch { row, order, probs } = scratch;
    row.clear();
    row.extend_from_slice(logits);
    row[mask_id as usize] = f32::NEG_INFINITY;
    if let Some(sup) = suppress {
        row[sup as usize] = f32::NEG_INFINITY;
    }

    if cfg.temperature <= 0.0 {
        return argmax(row) as i32;
    }

    // temperature scaling
    for x in row.iter_mut() {
        *x /= cfg.temperature;
    }
    // top-k / top-p filtering for maskgit-plus
    if let Strategy::MaskgitPlus { top_k, top_p } = cfg.strategy {
        order.clear();
        order.extend(0..row.len());
        order.sort_by(|&a, &b| row[b].partial_cmp(&row[a]).unwrap());
        if top_k > 0 {
            for &i in order.iter().skip(top_k) {
                row[i] = f32::NEG_INFINITY;
            }
        }
        if top_p < 1.0 {
            softmax_into(row, probs);
            let mut cum = 0.0;
            let mut cut = row.len();
            for (rank, &i) in order.iter().enumerate() {
                cum += probs[i];
                if cum >= top_p {
                    cut = rank + 1;
                    break;
                }
            }
            for &i in order.iter().skip(cut) {
                row[i] = f32::NEG_INFINITY;
            }
        }
    }
    softmax_into(row, probs);
    rng.categorical(probs) as i32
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, x) in xs.iter().enumerate() {
        if *x > xs[best] {
            best = i;
        }
    }
    best
}

fn softmax_into(xs: &[f32], out: &mut Vec<f32>) {
    out.clear();
    let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    if m == f32::NEG_INFINITY {
        out.resize(xs.len(), 0.0);
        return;
    }
    out.extend(xs.iter().map(|x| (x - m).exp()));
    let z: f32 = out.iter().sum();
    for e in out.iter_mut() {
        *e /= z;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn logits_with_peak(v: usize, peak: usize, val: f32) -> Vec<f32> {
        let mut row = vec![0.0; v];
        row[peak] = val;
        row
    }

    #[test]
    fn greedy_unmasks_highest_confidence_position() {
        let v = 8;
        let mut logits = vec![0.0; 4 * v];
        logits[(1 * v)..(1 * v + v)].copy_from_slice(&logits_with_peak(v, 5, 9.0));
        let conf = vec![0.3, 0.99, 0.2, 0.1];
        let gen_tokens = vec![1, 1, 1, 7]; // mask=1; last not masked
        let inp = UnmaskInput {
            logits: &logits,
            conf: &conf,
            gen_tokens: &gen_tokens,
            block_lo: 0,
            block_hi: 4,
            vocab: v,
            mask_id: 1,
            eos_id: 2,
        };
        let mut rng = SplitMix::new(1);
        let d = decide_unmask(&SamplerCfg::llada(), &inp, &mut rng);
        assert_eq!(d.positions, vec![1]);
        assert_eq!(d.tokens, vec![5]);
    }

    #[test]
    fn parallel_decoding_unmasks_above_threshold() {
        let v = 8;
        let logits = vec![0.0; 4 * v];
        let conf = vec![0.95, 0.99, 0.2, 0.96];
        let gen_tokens = vec![1, 1, 1, 1];
        let inp = UnmaskInput {
            logits: &logits,
            conf: &conf,
            gen_tokens: &gen_tokens,
            block_lo: 0,
            block_hi: 4,
            vocab: v,
            mask_id: 1,
            eos_id: 2,
        };
        let mut rng = SplitMix::new(1);
        let cfg = SamplerCfg::llada().with_parallel(0.9);
        let d = decide_unmask(&cfg, &inp, &mut rng);
        assert_eq!(d.positions, vec![0, 1, 3]);
    }

    #[test]
    fn eos_guard_suppresses_eos_before_existing_content() {
        let v = 8;
        // EOS (id 2) is the argmax; token 4 is second
        let mut logits = vec![0.0; 2 * v];
        logits[0..v].copy_from_slice(&{
            let mut r = logits_with_peak(v, 2, 9.0);
            r[4] = 5.0;
            r
        });
        let conf = vec![0.9, 0.1];
        let gen_tokens = vec![1, 5]; // later position holds content (id 5)
        let inp = UnmaskInput {
            logits: &logits,
            conf: &conf,
            gen_tokens: &gen_tokens,
            block_lo: 0,
            block_hi: 2,
            vocab: v,
            mask_id: 1,
            eos_id: 2,
        };
        let mut rng = SplitMix::new(1);
        let d = decide_unmask(&SamplerCfg::llada(), &inp, &mut rng);
        assert_eq!(d.positions, vec![0]);
        assert_eq!(d.tokens, vec![4], "EOS must be suppressed before content");

        // without guard it picks EOS
        let mut cfg = SamplerCfg::llada();
        cfg.eos_guard = false;
        let d2 = decide_unmask(&cfg, &inp, &mut rng);
        assert_eq!(d2.tokens, vec![2]);
    }

    #[test]
    fn eos_guard_allows_tail_eos_fill() {
        let v = 8;
        let logits = logits_with_peak(v, 2, 9.0); // EOS is argmax
        let conf = vec![0.9];
        let gen_tokens = vec![1]; // single masked tail position
        let inp = UnmaskInput {
            logits: &logits,
            conf: &conf,
            gen_tokens: &gen_tokens,
            block_lo: 0,
            block_hi: 1,
            vocab: v,
            mask_id: 1,
            eos_id: 2,
        };
        let mut rng = SplitMix::new(1);
        let d = decide_unmask(&SamplerCfg::llada(), &inp, &mut rng);
        assert_eq!(d.tokens, vec![2], "tail EOS must be allowed");
    }

    #[test]
    fn mask_token_never_sampled() {
        let v = 4;
        let row = logits_with_peak(v, 1, 99.0); // mask id has huge logit
        let mut rng = SplitMix::new(1);
        let t = sample_token(&SamplerCfg::llada(), &row, &mut rng, None, 1);
        assert_ne!(t, 1);
    }

    #[test]
    fn temperature_zero_is_greedy_for_maskgit() {
        let v = 8;
        let row = logits_with_peak(v, 6, 3.0);
        let mut rng = SplitMix::new(1);
        let t = sample_token(&SamplerCfg::dream(), &row, &mut rng, None, 1);
        assert_eq!(t, 6);
    }

    #[test]
    fn scratch_variant_matches_allocating_variant() {
        let v = 8;
        let mut row = vec![0.0; v];
        row[3] = 5.0;
        row[4] = 4.9;
        row[6] = 4.8;
        let cfg = SamplerCfg {
            strategy: Strategy::MaskgitPlus { top_k: 3, top_p: 0.95 },
            temperature: 0.7,
            parallel_threshold: None,
            eos_guard: false,
        };
        let mut scratch = SamplerScratch::default();
        for seed in 0..20u64 {
            let mut r1 = SplitMix::new(seed);
            let mut r2 = SplitMix::new(seed);
            let a = sample_token(&cfg, &row, &mut r1, Some(2), 1);
            let b = sample_token_with(&cfg, &row, &mut r2, Some(2), 1, &mut scratch);
            assert_eq!(a, b, "seed {seed}: scratch reuse must not change sampling");
        }
    }

    #[test]
    fn top_k_filters_tail() {
        let v = 8;
        let mut row = vec![0.0; v];
        row[3] = 5.0;
        row[4] = 4.9;
        let cfg = SamplerCfg {
            strategy: Strategy::MaskgitPlus { top_k: 2, top_p: 1.0 },
            temperature: 1.0,
            parallel_threshold: None,
            eos_guard: false,
        };
        let mut rng = SplitMix::new(1);
        for _ in 0..50 {
            let t = sample_token(&cfg, &row, &mut rng, None, 1);
            assert!(t == 3 || t == 4, "got {t}");
        }
    }
}
