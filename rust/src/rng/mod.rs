//! PRNG substrate: SplitMix64 (matching `python/compile/tasks.py` exactly so
//! both sides generate identical eval sets) plus the sampling distributions
//! the coordinator needs (uniform, categorical, Poisson/exponential
//! arrivals).

#[derive(Debug, Clone)]
pub struct SplitMix {
    state: u64,
}

impl SplitMix {
    pub fn new(seed: u64) -> Self {
        SplitMix { state: seed }
    }

    pub fn next64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform integer in [0, n). Matches the python `below` (mod-based —
    /// the tiny modulo bias is irrelevant and determinism matters more).
    pub fn below(&mut self, n: u64) -> u64 {
        self.next64() % n
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Exponential inter-arrival time with the given rate (per second).
    pub fn exp(&mut self, rate: f64) -> f64 {
        let u = self.f64().max(1e-12);
        -u.ln() / rate
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f64 = weights.iter().map(|w| *w as f64).sum();
        if total <= 0.0 {
            return self.below(weights.len() as u64) as usize;
        }
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= *w as f64;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_python_splitmix() {
        // reference values produced by python/compile/tasks.py SplitMix(42)
        let mut r = SplitMix::new(42);
        let got: Vec<u64> = (0..4).map(|_| r.next64()).collect();
        assert_eq!(
            got,
            vec![
                13679457532755275413,
                2949826092126892291,
                5139283748462763858,
                6349198060258255764,
            ]
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix::new(7);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn categorical_respects_zero_weights() {
        let mut r = SplitMix::new(3);
        for _ in 0..100 {
            let i = r.categorical(&[0.0, 1.0, 0.0]);
            assert_eq!(i, 1);
        }
    }

    #[test]
    fn range_is_inclusive() {
        let mut r = SplitMix::new(1);
        let mut seen = [false; 3];
        for _ in 0..100 {
            seen[(r.range(5, 7) - 5) as usize] = true;
        }
        assert!(seen.iter().all(|s| *s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix::new(9);
        let mut v: Vec<u32> = (0..20).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..20).collect::<Vec<_>>());
    }
}
